//! Property-based tests over the simulator invariants, using the
//! `exanest::testing::forall` harness (no offline proptest crate; same
//! seeded-generate / replayable-failure discipline).

use exanest::mpi::collectives::{bcast_schedule, recursive_doubling_schedule};
use exanest::mpi::{progress, pt2pt, Placement, World};
use exanest::network::{Fabric, FaultPlan, NetworkModel, RoutePolicy, RouterMesh};
use exanest::prop_assert;
use exanest::sim::{Engine, Resource, SimDuration, SimTime};
use exanest::testing::forall;
use exanest::topology::{route, Dir, Gvas, MpsocId, QfdbId, SystemConfig, Topology};

#[test]
fn prop_gvas_roundtrip() {
    forall("gvas pack/unpack roundtrip", 500, |rng| {
        let g = Gvas::new(
            rng.below(1 << 16) as u16,
            rng.below(1 << 22) as u32,
            rng.below(1 << 3) as u8,
            rng.below(1 << 39),
        )
        .map_err(|e| e.to_string())?;
        prop_assert!(Gvas::unpack(g.pack()) == Ok(g), "u128 roundtrip {g}");
        prop_assert!(Gvas::from_bytes(g.to_bytes()) == g, "byte roundtrip {g}");
        Ok(())
    });
}

#[test]
fn prop_route_reaches_and_matches_distance() {
    let topo = Topology::new(SystemConfig::prototype());
    forall("DOR route reaches dst with torus distance", 300, |rng| {
        let n = topo.cfg.num_qfdbs() as u64;
        let a = QfdbId(rng.below(n) as u32);
        let b = QfdbId(rng.below(n) as u32);
        let dirs = topo.qfdb_route(a, b);
        let mut cur = a;
        for d in &dirs {
            cur = topo.qfdb_neighbor(cur, *d);
        }
        prop_assert!(cur == b, "route {a:?}->{b:?} ended at {cur:?}");
        prop_assert!(
            dirs.len() == topo.qfdb_distance(a, b),
            "route len {} != distance {}",
            dirs.len(),
            topo.qfdb_distance(a, b)
        );
        Ok(())
    });
}

#[test]
fn prop_route_is_dimension_ordered() {
    // deadlock freedom rests on X-then-Y-then-Z ordering
    let topo = Topology::new(SystemConfig::prototype());
    forall("routes are dimension ordered", 300, |rng| {
        let n = topo.cfg.num_qfdbs() as u64;
        let a = QfdbId(rng.below(n) as u32);
        let b = QfdbId(rng.below(n) as u32);
        let dirs = topo.qfdb_route(a, b);
        let phase = |d: &exanest::topology::Dir| match d {
            exanest::topology::Dir::XPlus | exanest::topology::Dir::XMinus => 0,
            exanest::topology::Dir::YPlus | exanest::topology::Dir::YMinus => 1,
            _ => 2,
        };
        let phases: Vec<i32> = dirs.iter().map(phase).collect();
        let mut sorted = phases.clone();
        sorted.sort();
        prop_assert!(phases == sorted, "not dimension ordered: {phases:?}");
        Ok(())
    });
}

#[test]
fn prop_path_hops_and_routers_consistent() {
    let topo = Topology::new(SystemConfig::prototype());
    forall("path router count = torus hops + 1 (when any)", 300, |rng| {
        let n = topo.cfg.num_mpsocs() as u64;
        let a = exanest::topology::MpsocId(rng.below(n) as u32);
        let b = exanest::topology::MpsocId(rng.below(n) as u32);
        let p = route(&topo, a, b);
        let torus_hops = p.hops().iter().filter(|h| h.link.is_torus()).count();
        if torus_hops > 0 {
            prop_assert!(
                p.routers == torus_hops + 1,
                "{a:?}->{b:?}: {} routers for {torus_hops} torus hops",
                p.routers
            );
        } else {
            prop_assert!(p.routers == 0, "intra-QFDB path has routers");
        }
        Ok(())
    });
}

#[test]
fn prop_bcast_schedule_covers_all_once() {
    forall("binomial bcast covers each rank exactly once", 200, |rng| {
        let n = rng.range(2, 700) as usize;
        let mut got = vec![false; n];
        got[0] = true;
        for step in bcast_schedule(n) {
            for (s, d) in step {
                prop_assert!(got[s], "n={n}: {s} sends before covered");
                prop_assert!(!got[d], "n={n}: {d} covered twice");
                got[d] = true;
            }
        }
        prop_assert!(got.iter().all(|&x| x), "n={n}: not all covered");
        Ok(())
    });
}

#[test]
fn prop_recursive_doubling_is_allreduce() {
    // executing the schedule with real vectors yields the global sum on
    // every rank
    forall("recursive doubling computes the global sum", 100, |rng| {
        let n = 1usize << rng.range(1, 6);
        let mut vals: Vec<i64> = (0..n).map(|_| rng.below(1000) as i64).collect();
        let want: i64 = vals.iter().sum();
        for step in recursive_doubling_schedule(n) {
            let mut next = vals.clone();
            for (a, b) in step {
                let s = vals[a] + vals[b];
                next[a] = s;
                next[b] = s;
            }
            vals = next;
        }
        prop_assert!(vals.iter().all(|&v| v == want), "n={n}: {vals:?} != {want}");
        Ok(())
    });
}

#[test]
fn prop_resource_fifo_and_conservation() {
    forall("resource occupancy is FIFO + work conserving", 200, |rng| {
        let mut r = Resource::new();
        let mut total = 0u64;
        let mut last_end = SimTime::ZERO;
        for _ in 0..20 {
            let at = SimTime(rng.below(1_000_000));
            let dur = SimDuration(rng.below(10_000) + 1);
            let (start, end) = r.acquire(at, dur);
            prop_assert!(start >= at, "start before request");
            prop_assert!(start >= last_end, "overlapping grants");
            prop_assert!(end.0 - start.0 == dur.0, "duration mangled");
            last_end = end;
            total += dur.0;
        }
        prop_assert!(r.busy_time().0 == total, "busy time drifted");
        Ok(())
    });
}

#[test]
fn prop_eager_latency_monotone_in_distance() {
    let cfg = SystemConfig::prototype();
    forall("pt2pt latency grows with torus distance", 60, |rng| {
        let topo = Topology::new(cfg.clone());
        let qa = QfdbId(rng.below(32) as u32);
        let qb = QfdbId(rng.below(32) as u32);
        let da = topo.qfdb_distance(QfdbId(0), qa);
        let db = topo.qfdb_distance(QfdbId(0), qb);
        if da == db {
            return Ok(());
        }
        let mut w = World::new(cfg.clone(), 128, Placement::PerMpsoc);
        let ra = (qa.0 * 4) as usize;
        let rb = (qb.0 * 4) as usize;
        if ra == 0 || rb == 0 {
            return Ok(());
        }
        let la = pt2pt::send_recv(&mut w, 0, ra, 0).recv_done;
        w.reset();
        let lb = pt2pt::send_recv(&mut w, 0, rb, 0).recv_done;
        let (near, far) = if da < db { (la, lb) } else { (lb, la) };
        prop_assert!(near <= far, "distance {da} vs {db}: {near:?} vs {far:?}");
        Ok(())
    });
}

#[test]
fn prop_nonblocking_reproduces_blocking_to_the_nanosecond() {
    // Refactor seam: the event-driven send_recv (isend + irecv + wait on
    // the progress engine) must reproduce the closed-form blocking oracle
    // exactly — over random placements, endpoints, sizes and chains of
    // messages (so fabric occupancy carries over between operations).
    let cfg = SystemConfig::prototype();
    forall("isend+wait == blocking send_recv (ps exact)", 40, |rng| {
        let placement = if rng.below(2) == 0 { Placement::PerCore } else { Placement::PerMpsoc };
        let n = 16usize;
        let mut oracle = World::new(cfg.clone(), n, placement);
        let mut event = World::new(cfg.clone(), n, placement);
        for _ in 0..8 {
            let src = rng.below(n as u64) as usize;
            let dst = rng.below(n as u64) as usize;
            if src == dst {
                continue;
            }
            let bytes = [0usize, 8, 32, 33, 64, 4096, 100_000][rng.below(7) as usize];
            // oracle: closed-form message() with the old blocking clock
            // semantics (clocks *set* to the completion times)
            let ts = oracle.clocks[src];
            let tr = oracle.clocks[dst];
            let m = pt2pt::message(&mut oracle, src, dst, bytes, ts, tr);
            oracle.clocks[src] = m.send_done;
            oracle.clocks[dst] = m.recv_done;
            // event-driven path
            let r = pt2pt::send_recv(&mut event, src, dst, bytes);
            prop_assert!(
                r.send_done == m.send_done && r.recv_done == m.recv_done,
                "{src}->{dst} {bytes} B: event ({:?}, {:?}) vs oracle ({:?}, {:?})",
                r.send_done,
                r.recv_done,
                m.send_done,
                m.recv_done
            );
            prop_assert!(
                event.clocks[src] == oracle.clocks[src]
                    && event.clocks[dst] == oracle.clocks[dst],
                "clocks diverged after {src}->{dst}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_route_cached_equals_route() {
    // Refactor seam: the dense route cache must be exact for every
    // endpoint pair, including repeated (cache-hit) queries.
    let cfg = SystemConfig::prototype();
    forall("Fabric::route_cached == route", 150, |rng| {
        let mut fab = Fabric::new(cfg.clone());
        let n = cfg.num_mpsocs() as u64;
        for _ in 0..4 {
            let a = MpsocId(rng.below(n) as u32);
            let b = MpsocId(rng.below(n) as u32);
            let fresh = fab.route(a, b);
            for query in 0..2 {
                let cached = fab.route_cached(a, b);
                prop_assert!(
                    cached.src == fresh.src
                        && cached.dst == fresh.dst
                        && cached.hops() == fresh.hops()
                        && cached.routers == fresh.routers
                        && cached.switches == fresh.switches,
                    "{a:?}->{b:?} query {query}: cached {cached:?} != fresh {fresh:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cell_level_zero_load_matches_oracle() {
    // The router-mesh seam: at zero load, cell-level deterministic
    // routing must reproduce the closed-form `pt2pt::message` oracle —
    // exactly (< 1%) for eager messages on any path and for rendez-vous
    // on single-link paths; multi-link rendez-vous may only be *faster*
    // (cells genuinely cut through intermediate routers, where the flow
    // model store-and-forwards whole blocks per hop).
    let cfg = SystemConfig::prototype();
    let topo = Topology::new(cfg.clone());
    forall("cell-level zero load == oracle", 25, |rng| {
        let n = cfg.num_mpsocs();
        let a = rng.below(n as u64) as usize;
        let b = rng.below(n as u64) as usize;
        if a == b {
            return Ok(());
        }
        let p = route(&topo, MpsocId(a as u32), MpsocId(b as u32));
        let single_link = p.hops().len() <= 1;
        let mut sizes: Vec<usize> = vec![0, 8, 32];
        if single_link {
            sizes.extend([64, 4096, 64 * 1024]);
        }
        for bytes in sizes {
            let mut flow = World::new(cfg.clone(), n, Placement::PerMpsoc);
            let mut cell = World::with_model(
                cfg.clone(),
                n,
                Placement::PerMpsoc,
                NetworkModel::cell(RoutePolicy::Deterministic),
            );
            let f = pt2pt::message(&mut flow, a, b, bytes, SimTime::ZERO, SimTime::ZERO);
            let c = pt2pt::message(&mut cell, a, b, bytes, SimTime::ZERO, SimTime::ZERO);
            let rel = (c.recv_done.ns() - f.recv_done.ns()).abs() / f.recv_done.ns();
            prop_assert!(
                rel < 0.01,
                "{a}->{b} {bytes} B: cell {:?} vs oracle {:?} ({rel:.4} off)",
                c.recv_done,
                f.recv_done
            );
        }
        // multi-link rendez-vous: cut-through must never be slower
        if !single_link {
            let mut flow = World::new(cfg.clone(), n, Placement::PerMpsoc);
            let mut cell = World::with_model(
                cfg.clone(),
                n,
                Placement::PerMpsoc,
                NetworkModel::cell(RoutePolicy::Deterministic),
            );
            let f = pt2pt::message(&mut flow, a, b, 64 * 1024, SimTime::ZERO, SimTime::ZERO);
            let c = pt2pt::message(&mut cell, a, b, 64 * 1024, SimTime::ZERO, SimTime::ZERO);
            prop_assert!(
                c.recv_done <= f.recv_done + SimDuration::from_ns(1.0),
                "{a}->{b}: cut-through {:?} slower than store-and-forward {:?}",
                c.recv_done,
                f.recv_done
            );
        }
        Ok(())
    });
}

#[test]
fn prop_adaptive_degenerates_to_dimension_order_when_idle() {
    // On an idle healthy mesh the adaptive policy's congestion signals
    // are all ties, so it must route and time exactly like the static
    // dimension-order tables.
    let cfg = SystemConfig::prototype();
    let topo = Topology::new(cfg.clone());
    forall("idle adaptive == dimension order", 60, |rng| {
        let nq = cfg.num_qfdbs() as u64;
        let qa = QfdbId(rng.below(nq) as u32);
        let qb = QfdbId(rng.below(nq) as u32);
        let det = RouterMesh::new(topo.clone(), RoutePolicy::Deterministic, FaultPlan::none());
        let ada = RouterMesh::new(topo.clone(), RoutePolicy::Adaptive, FaultPlan::none());
        prop_assert!(
            ada.probe_route(qa, qb, SimTime::ZERO) == det.probe_route(qa, qb, SimTime::ZERO),
            "{qa:?}->{qb:?}: adaptive route diverges on an idle mesh"
        );
        prop_assert!(
            det.probe_route(qa, qb, SimTime::ZERO) == topo.qfdb_route(qa, qb),
            "{qa:?}->{qb:?}: deterministic mesh route != static DOR table"
        );
        if qa != qb {
            let a = topo.network_mpsoc(qa);
            let b = topo.network_mpsoc(qb);
            let mut det = det;
            let mut ada = ada;
            let bytes = [256usize, 4096, 16 * 1024][rng.below(3) as usize];
            let d = det.block(a, b, SimTime::ZERO, bytes, false);
            let m = ada.block(a, b, SimTime::ZERO, bytes, false);
            prop_assert!(m == d, "{qa:?}->{qb:?} {bytes} B: adaptive {m:?} != DOR {d:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_route_cached_valid_after_reset() {
    // Satellite regression: `Fabric::reset` keeps the route cache, which
    // must therefore stay exact after arbitrary traffic + reset cycles.
    let cfg = SystemConfig::prototype();
    forall("route cache exact across reset", 40, |rng| {
        let mut fab = Fabric::new(cfg.clone());
        let n = cfg.num_mpsocs() as u64;
        let mut pairs = Vec::new();
        for _ in 0..4 {
            let a = MpsocId(rng.below(n) as u32);
            let b = MpsocId(rng.below(n) as u32);
            let p = fab.route_cached(a, b);
            if a != b {
                fab.small_cell(&p, SimTime::ZERO, 64);
                fab.rdma_block(&p, SimTime::ZERO, 4096, true);
            }
            pairs.push((a, b));
        }
        fab.reset();
        for (a, b) in pairs {
            let cached = fab.route_cached(a, b);
            let fresh = fab.route(a, b);
            prop_assert!(
                cached.hops() == fresh.hops()
                    && cached.routers == fresh.routers
                    && cached.switches == fresh.switches,
                "{a:?}->{b:?}: cache corrupted across reset"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_tracing_is_timing_invisible() {
    // Tentpole acceptance: the flight recorder is a pure observer.
    // Identical worlds with tracing on and off must produce ps-identical
    // timings under cell-level traffic — deterministic and adaptive
    // routing, healthy and faulty fabrics, point-to-point and
    // collective patterns.  (`sched::tests` covers the scheduler side.)
    let cfg = SystemConfig::two_blades();
    forall("trace on == trace off (ps)", 20, |rng| {
        let policy = if rng.below(2) == 0 {
            RoutePolicy::Deterministic
        } else {
            RoutePolicy::Adaptive
        };
        let model = if rng.below(2) == 0 {
            NetworkModel::cell(policy)
        } else {
            NetworkModel::cell_with_faults(
                policy,
                FaultPlan::none().fail_torus(QfdbId(1), Dir::XMinus, SimTime::ZERO),
            )
        };
        let n = 8usize;
        let mut plain = World::with_model(cfg.clone(), n, Placement::PerMpsoc, model.clone());
        let mut traced = World::with_model(cfg.clone(), n, Placement::PerMpsoc, model);
        traced.enable_tracing(1 << 16);
        for _ in 0..3 {
            let a = rng.below(n as u64) as usize;
            let mut b = rng.below(n as u64) as usize;
            if a == b {
                b = (b + 1) % n;
            }
            let bytes = [64usize, 4096, 64 * 1024][rng.below(3) as usize];
            let p = pt2pt::message(&mut plain, a, b, bytes, SimTime::ZERO, SimTime::ZERO);
            let t = pt2pt::message(&mut traced, a, b, bytes, SimTime::ZERO, SimTime::ZERO);
            prop_assert!(
                p.recv_done == t.recv_done,
                "{a}->{b} {bytes} B: traced {:?} != plain {:?}",
                t.recv_done,
                p.recv_done
            );
        }
        let cp = exanest::mpi::collectives::allreduce(&mut plain, 1024);
        let ct = exanest::mpi::collectives::allreduce(&mut traced, 1024);
        prop_assert!(cp == ct, "allreduce traced {ct:?} != plain {cp:?}");
        prop_assert!(!traced.trace_records().is_empty(), "traced run must retain spans");
        prop_assert!(plain.trace_records().is_empty(), "untraced run must record nothing");
        Ok(())
    });
}

#[test]
fn prop_trace_spans_balanced_and_worker_invariant() {
    // Every recorded span is well formed (t1 >= t0, i.e. no negative
    // `dur` in the exported JSON), and the rank-level trace is identical
    // at 1 and 4 DES workers.  Only the par-runtime window markers
    // (`Track::Par`) and the mesh hop spans depend on the execution
    // strategy — worker replicas run with their recorders off — so those
    // are excluded from the equality.
    use exanest::telemetry::{SpanKind, Track};
    forall("trace spans balanced + worker invariant", 8, |rng| {
        let bytes = [1024usize, 4096, 1 << 16][rng.below(3) as usize];
        let n = [4usize, 8][rng.below(2) as usize];
        let mut runs = Vec::new();
        for workers in [1usize, 4] {
            let mut cfg = SystemConfig::two_blades();
            cfg.sim_workers = workers;
            let mut w = World::with_model(
                cfg,
                n,
                Placement::PerMpsoc,
                NetworkModel::cell(RoutePolicy::Deterministic),
            );
            w.enable_tracing(1 << 16);
            let lat = exanest::mpi::collectives::allreduce(&mut w, bytes);
            let recs = w.trace_records();
            prop_assert!(!recs.is_empty(), "w={workers}: no spans recorded");
            prop_assert!(w.trace_dropped() == 0, "w={workers}: ring overflowed");
            for r in &recs {
                prop_assert!(
                    r.t1 >= r.t0,
                    "w={workers}: unbalanced span {:?} [{:?}, {:?}]",
                    r.kind,
                    r.t0,
                    r.t1
                );
            }
            let ranks: Vec<_> = recs
                .into_iter()
                .filter(|r| !matches!(r.track, Track::Par) && r.kind != SpanKind::Hop)
                .collect();
            runs.push((lat, ranks));
        }
        prop_assert!(
            runs[0].0 == runs[1].0,
            "traced latency differs across workers: {:?} vs {:?}",
            runs[0].0,
            runs[1].0
        );
        prop_assert!(
            runs[0].1 == runs[1].1,
            "rank-level trace differs across workers ({} vs {} spans)",
            runs[0].1.len(),
            runs[1].1.len()
        );
        Ok(())
    });
}

#[test]
fn prop_telemetry_cleared_but_enabled_across_reset() {
    // Satellite regression, twin of the route-cache test above:
    // `World::reset` (→ `Engine::clear` / `Fabric::reset`) must empty the
    // flight recorder and the telemetry windows while keeping both
    // enabled, and a re-run on the reset world must trace identically.
    let cfg = SystemConfig::two_blades();
    forall("telemetry reset: empty but enabled", 15, |rng| {
        let n = 8usize;
        let mut w = World::with_model(
            cfg.clone(),
            n,
            Placement::PerMpsoc,
            NetworkModel::cell(RoutePolicy::Deterministic),
        );
        w.enable_tracing(1 << 14);
        let bytes = [256usize, 4096][rng.below(2) as usize];
        let first = exanest::mpi::collectives::allreduce(&mut w, bytes);
        w.fabric.sample_telemetry(w.max_clock());
        let recs_before = w.trace_records();
        prop_assert!(!recs_before.is_empty(), "traced run records spans");
        prop_assert!(w.fabric.telemetry().len() > 0, "sampled run has a telemetry window");
        w.reset();
        prop_assert!(w.tracing_enabled(), "reset must keep the recorder enabled");
        prop_assert!(w.trace_records().is_empty(), "reset must clear recorded spans");
        prop_assert!(w.trace_dropped() == 0, "reset must clear the eviction count");
        prop_assert!(w.fabric.telemetry().is_empty(), "reset must clear telemetry windows");
        let second = exanest::mpi::collectives::allreduce(&mut w, bytes);
        prop_assert!(first == second, "reset world re-times differently: {second:?} vs {first:?}");
        let recs_after = w.trace_records();
        prop_assert!(
            recs_after == recs_before,
            "post-reset trace diverges: {} vs {} spans",
            recs_after.len(),
            recs_before.len()
        );
        Ok(())
    });
}

/// Reference event-queue model for the timing-wheel proptest: a flat
/// list popped by minimum (time, seq) — the semantics of the original
/// `BinaryHeap` engine.
mod refqueue {
    pub type Entry = (u64, u64, u32); // (at, seq, id)

    pub fn peek(q: &[Entry]) -> Option<Entry> {
        q.iter().copied().min_by_key(|&(at, seq, _)| (at, seq))
    }

    pub fn pop(q: &mut Vec<Entry>) -> Option<Entry> {
        let min = peek(q)?;
        let idx = q.iter().position(|&e| e == min).unwrap();
        Some(q.remove(idx))
    }
}

#[test]
fn prop_timing_wheel_is_a_drop_in_for_the_heap() {
    // The tentpole scheduler contract: the hierarchical timing wheel must
    // pop in exactly the (time, seq) order of the old global heap under
    // random interleavings of schedule / post-into-the-past / next /
    // run_until / peek / clear — including same-tick FIFO ties, wheel
    // rollover (timestamps many horizons out) and far-future
    // overflow-bucket migration.
    const HORIZON: u64 = 1 << 26; // NUM_SLOTS * SLOT_PS = 1024 * 2^16 ps
    forall("timing wheel == reference heap", 120, |rng| {
        let mut e: Engine<u32> = Engine::new();
        let mut model: Vec<refqueue::Entry> = Vec::new();
        let mut mseq = 0u64;
        let mut mnow = 0u64;
        let mut next_id = 0u32;
        for step in 0..80 {
            match rng.below(10) {
                0..=4 => {
                    // schedule at now + delta, deltas spanning same-slot,
                    // in-wheel, multi-lap and far-overflow distances
                    let delta = match rng.below(4) {
                        0 => rng.below(1 << 16),
                        1 => rng.below(HORIZON),
                        2 => rng.below(3 * HORIZON),
                        _ => rng.below(1 << 40),
                    };
                    let at = mnow + delta;
                    e.schedule(SimTime(at), next_id);
                    model.push((at, mseq, next_id));
                    mseq += 1;
                    next_id += 1;
                }
                5 => {
                    // rank-local post, possibly into the past
                    let at = rng.below(mnow + 1);
                    e.post(SimTime(at), next_id);
                    model.push((at, mseq, next_id));
                    mseq += 1;
                    next_id += 1;
                }
                6..=7 => {
                    let got = e.next();
                    let want = refqueue::pop(&mut model);
                    if let Some((at, _, _)) = want {
                        mnow = mnow.max(at);
                    }
                    prop_assert!(
                        got.map(|(t, i)| (t.0, i)) == want.map(|(at, _, id)| (at, id)),
                        "step {step}: next {got:?} vs {want:?}"
                    );
                    prop_assert!(e.now().0 == mnow, "step {step}: now {:?} vs {mnow}", e.now());
                }
                8 => {
                    let deadline = mnow + rng.below(2 * HORIZON);
                    let mut got: Vec<(u64, u32)> = Vec::new();
                    e.run_until(&mut got, SimTime(deadline), |g, _, t, i| g.push((t.0, i)));
                    let mut want: Vec<(u64, u32)> = Vec::new();
                    while let Some((at, _, _)) = refqueue::peek(&model) {
                        if at > deadline {
                            break;
                        }
                        let (at, _, id) = refqueue::pop(&mut model).unwrap();
                        mnow = mnow.max(at);
                        want.push((at, id));
                    }
                    mnow = mnow.max(deadline);
                    prop_assert!(got == want, "step {step}: run_until {got:?} vs {want:?}");
                    prop_assert!(e.now().0 == mnow, "step {step}: now after run_until");
                }
                _ => {
                    if rng.below(6) == 0 {
                        e.clear();
                        model.clear();
                        mnow = 0;
                    } else {
                        let want = refqueue::peek(&model).map(|(at, _, _)| at);
                        prop_assert!(
                            e.peek_time().map(|t| t.0) == want,
                            "step {step}: peek {:?} vs {want:?}",
                            e.peek_time()
                        );
                    }
                }
            }
            prop_assert!(
                e.pending() == model.len(),
                "step {step}: pending {} vs {}",
                e.pending(),
                model.len()
            );
        }
        // drain fully in lockstep
        loop {
            let got = e.next();
            let want = refqueue::pop(&mut model);
            prop_assert!(
                got.map(|(t, i)| (t.0, i)) == want.map(|(at, _, id)| (at, id)),
                "drain: {got:?} vs {want:?}"
            );
            if got.is_none() {
                break;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_train_batching_matches_event_path() {
    // The tentpole parity contract: cell-train batching must be
    // ps-identical to per-cell event simulation under random traffic —
    // idle meshes, hotspot chains (blocks issued back-to-back into still-
    // busy wires), both policies, and fault plans (already-down links
    // batch onto the detour route; future fault times force both meshes
    // onto the event path).
    let cfg = SystemConfig::prototype();
    let topo = Topology::new(cfg.clone());
    forall("batched trains == per-cell events (ps exact)", 30, |rng| {
        let policy = if rng.below(2) == 0 {
            RoutePolicy::Deterministic
        } else {
            RoutePolicy::Adaptive
        };
        let nq = cfg.num_qfdbs() as u64;
        let faults = match rng.below(3) {
            0 => FaultPlan::none(),
            1 => FaultPlan::none().fail_torus(
                QfdbId(rng.below(nq) as u32),
                Dir::XPlus,
                SimTime::ZERO,
            ),
            _ => FaultPlan::none().fail_torus(
                QfdbId(rng.below(nq) as u32),
                Dir::YMinus,
                SimTime::from_us(30.0),
            ),
        };
        let mut fast = RouterMesh::new(topo.clone(), policy, faults.clone());
        let mut slow = RouterMesh::new(topo.clone(), policy, faults);
        slow.set_batching(false);
        let n = cfg.num_mpsocs() as u64;
        let mut at = SimTime::ZERO;
        for k in 0..8 {
            let a = MpsocId(rng.below(n) as u32);
            let b = MpsocId(rng.below(n) as u32);
            if a == b {
                continue;
            }
            if rng.below(4) == 0 {
                let payload = [0usize, 8, 32, 256][rng.below(4) as usize];
                let f = fast.small_cell(a, b, at, payload);
                let s = slow.small_cell(a, b, at, payload);
                prop_assert!(f == s, "call {k}: small_cell {a:?}->{b:?} {f:?} vs {s:?}");
            } else {
                let bytes = [1usize, 300, 4096, 16 * 1024][rng.below(4) as usize];
                let pipelined = rng.below(2) == 0;
                let f = fast.block(a, b, at, bytes, pipelined);
                let s = slow.block(a, b, at, bytes, pipelined);
                prop_assert!(
                    f == s,
                    "call {k}: block {a:?}->{b:?} {bytes} B at {at} — batched {f:?} vs events {s:?}"
                );
                if rng.below(2) == 0 {
                    at = f.0; // chain into the still-busy injection window
                }
            }
            if rng.below(3) == 0 {
                at = at + SimDuration::from_us(rng.below(40) as f64);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wait_all_order_is_irrelevant() {
    // completion times must not depend on the order requests are waited on
    let cfg = SystemConfig::prototype();
    forall("wait order independence", 30, |rng| {
        let n = 16usize;
        let mut wa = World::new(cfg.clone(), n, Placement::PerMpsoc);
        let mut wb = World::new(cfg.clone(), n, Placement::PerMpsoc);
        let bytes = [64usize, 4096, 65536][rng.below(3) as usize];
        // two disjoint pairs in flight together
        let post = |w: &mut World| {
            let s1 = progress::isend(w, 0, 1, bytes);
            let r1 = progress::irecv(w, 1, 0, bytes);
            let s2 = progress::isend(w, 2, 3, bytes);
            let r2 = progress::irecv(w, 3, 2, bytes);
            [s1, r1, s2, r2]
        };
        let ra = post(&mut wa);
        let rb = post(&mut wb);
        let da: Vec<SimTime> = ra.iter().map(|&q| progress::wait(&mut wa, q)).collect();
        let db: Vec<SimTime> = rb.iter().rev().map(|&q| progress::wait(&mut wb, q)).collect();
        for (i, &d) in da.iter().enumerate() {
            prop_assert!(
                db[3 - i] == d,
                "request {i}: forward-wait {d:?} != reverse-wait {:?}",
                db[3 - i]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_send_recv_never_goes_backwards() {
    let cfg = SystemConfig::prototype();
    forall("clocks are monotone under random traffic", 40, |rng| {
        let mut w = World::new(cfg.clone(), 64, Placement::PerCore);
        for _ in 0..50 {
            let a = rng.below(64) as usize;
            let b = rng.below(64) as usize;
            if a == b {
                continue;
            }
            let before = (w.clocks[a], w.clocks[b]);
            let bytes = match rng.below(3) {
                0 => 8,
                1 => 4096,
                _ => 128 * 1024,
            };
            let r = pt2pt::send_recv(&mut w, a, b, bytes as usize);
            prop_assert!(w.clocks[a] >= before.0, "sender clock regressed");
            prop_assert!(w.clocks[b] >= before.1, "receiver clock regressed");
            prop_assert!(r.recv_done >= r.send_done || bytes <= 32,
                "recv before send done for rendezvous");
        }
        Ok(())
    });
}

#[test]
fn prop_allreduce_phases_reduce_every_rank_count() {
    // executing the fold-in / recursive-doubling / fold-out phases with
    // real vectors yields the global sum on every rank, for ANY count
    use exanest::mpi::collectives::allreduce_phases;
    forall("generalized allreduce computes the global sum", 150, |rng| {
        let n = rng.range(1, 50) as usize;
        let mut vals: Vec<i64> = (0..n).map(|_| rng.below(1000) as i64 - 500).collect();
        let total: i64 = vals.iter().sum();
        let phases = allreduce_phases(n);
        for &(even, odd) in &phases.pre {
            let v = vals[even];
            vals[odd] += v;
        }
        for step in &phases.main {
            for &(a, b) in step {
                let s = vals[a] + vals[b];
                vals[a] = s;
                vals[b] = s;
            }
        }
        for &(odd, even) in &phases.post {
            vals[even] = vals[odd];
        }
        prop_assert!(
            vals.iter().all(|&v| v == total),
            "n={n}: ranks disagree with total {total}: {vals:?}"
        );
        Ok(())
    });
}

#[test]
fn prop_allreduce_timing_completes_for_any_rank_count() {
    // the timed schedule must run (no power-of-two assert) and cost at
    // least as much as the embedded power-of-two doubling phase alone
    use exanest::mpi::collectives;
    let cfg = SystemConfig::prototype();
    forall("allreduce timing at random rank counts", 15, |rng| {
        let n = rng.range(2, 40) as usize;
        let mut w = World::new(cfg.clone(), n, Placement::PerCore);
        let lat = collectives::allreduce(&mut w, 64);
        prop_assert!(lat.ns() > 0.0, "n={n}: zero allreduce latency");
        if !n.is_power_of_two() {
            let pof2 = n.next_power_of_two() / 2;
            let mut wp = World::new(cfg.clone(), pof2, Placement::PerCore);
            let base = collectives::allreduce(&mut wp, 64);
            prop_assert!(
                lat > base,
                "n={n}: folded allreduce {lat} not above pof2 {pof2} base {base}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_accel_and_software_allreduce_values_agree() {
    // the accelerator's hardware reduction tree and a sequential software
    // reduction must produce identical values (integer-valued f32 inputs
    // keep every sum exact, so tree reassociation cannot hide drift)
    use exanest::accel::{AccelAllreduce, AccelOp};
    forall("accel tree == software sequential reduction", 200, |rng| {
        let nranks = 1usize << rng.range(0, 5); // 1..=32
        let len = rng.range(1, 70) as usize;
        let op = [AccelOp::Sum, AccelOp::Min, AccelOp::Max][rng.below(3) as usize];
        let contributions: Vec<Vec<f32>> = (0..nranks)
            .map(|_| (0..len).map(|_| (rng.below(2000) as i64 - 1000) as f32).collect())
            .collect();
        let tree = AccelAllreduce::allreduce_f32_native(op, &contributions);
        // sequential software reference
        let mut seq = contributions[0].clone();
        for c in &contributions[1..] {
            for (a, b) in seq.iter_mut().zip(c) {
                *a = match op {
                    AccelOp::Sum => *a + *b,
                    AccelOp::Min => a.min(*b),
                    AccelOp::Max => a.max(*b),
                };
            }
        }
        prop_assert!(
            tree == seq,
            "op {op:?}, {nranks} ranks x {len}: tree {tree:?} != sequential {seq:?}"
        );
        Ok(())
    });
}

#[test]
fn prop_accel_beats_software_by_paper_margin_on_cell_model() {
    // Fig 19's headline: for small vectors at rendez-vous sizes the in-NI
    // accelerator cuts >= 80% off the software allreduce at 4-64 ranks —
    // asserted on the cell-level router mesh, where both paths pay real
    // per-cell forwarding
    use exanest::mpi::collectives::{allreduce_via, Backend};
    let cfg = SystemConfig::prototype();
    forall("accel >= 80% faster than software (cell model)", 8, |rng| {
        let n = [4usize, 16, 64][rng.below(3) as usize];
        let bytes = [64usize, 256][rng.below(2) as usize];
        let model = NetworkModel::cell(RoutePolicy::Deterministic);
        let mut w = World::with_model(cfg.clone(), n, Placement::PerMpsoc, model);
        let (sw, used_sw) = allreduce_via(&mut w, bytes, Backend::Software);
        prop_assert!(used_sw == Backend::Software, "software dispatch");
        w.reset();
        let (hw, used_hw) = allreduce_via(&mut w, bytes, Backend::Accel);
        prop_assert!(used_hw == Backend::Accel, "n={n} satisfies the accel constraints");
        prop_assert!(
            hw.ns() < 0.2 * sw.ns(),
            "n={n}, {bytes} B: accel {} us vs software {} us (< 80% improvement)",
            hw.us(),
            sw.us()
        );
        Ok(())
    });
}

#[test]
fn prop_proxy_overlap_is_bounded_and_all_faces_never_slower() {
    // the proxy engine's overlap accounting stays in [0, 1) and the
    // all-faces halo schedule never loses to the dim-staged barriers
    use exanest::apps::scaling::{run_point, AppParams, HaloSchedule, Mode, ProxyConfig};
    let cfg = SystemConfig::two_blades();
    forall("proxy overlap bounded; all-faces <= dim-staged", 6, |rng| {
        let ranks = [8usize, 16, 27][rng.below(3) as usize];
        let mut app = AppParams::minife();
        app.iters = 2;
        let staged = run_point(&cfg, &app, ranks, Mode::Weak, &ProxyConfig::default());
        let all = run_point(
            &cfg,
            &app,
            ranks,
            Mode::Weak,
            &ProxyConfig { halo: HaloSchedule::AllFaces, ..ProxyConfig::default() },
        );
        prop_assert!(
            (0.0..1.0).contains(&staged.overlap_fraction),
            "staged overlap {}",
            staged.overlap_fraction
        );
        prop_assert!(
            (0.0..1.0).contains(&all.overlap_fraction),
            "all-faces overlap {}",
            all.overlap_fraction
        );
        prop_assert!(
            all.time_s <= staged.time_s * 1.001,
            "ranks={ranks}: all-faces {} slower than dim-staged {}",
            all.time_s,
            staged.time_s
        );
        Ok(())
    });
}

#[test]
fn prop_scheduler_placements_injective_and_in_capacity() {
    // any placement the allocator produces — random job sizes, random
    // policies, random admission order with releases — is injective and
    // stays within the rack, as validated by RankMap::from_slots
    use exanest::mpi::RankMap;
    use exanest::sched::{Allocation, Policy, RackAlloc};
    let cfg = SystemConfig::prototype();
    forall("allocator placements are injective and in capacity", 60, |rng| {
        let mut rack = RackAlloc::new(&cfg);
        let mut live: Vec<(Allocation, usize, Placement)> = Vec::new();
        let mut all_slots = Vec::new();
        for _ in 0..12 {
            // occasionally release a live allocation (job finished)
            if !live.is_empty() && rng.below(3) == 0 {
                let i = rng.below(live.len() as u64) as usize;
                let (a, _, _) = live.swap_remove(i);
                rack.release(&a);
            }
            let policy =
                [Policy::Compact, Policy::BestFit, Policy::Scattered][rng.below(3) as usize];
            let placement =
                [Placement::PerCore, Placement::PerMpsoc][rng.below(2) as usize];
            let ranks = rng.range(1, 65) as usize;
            if let Some(a) = rack.allocate(ranks, placement, policy) {
                let slots = a.slots(&cfg, ranks, placement);
                prop_assert!(slots.len() == ranks, "one slot per rank");
                live.push((a, ranks, placement));
            }
            // the union of all live placements must form a valid RankMap
            all_slots.clear();
            for (a, ranks, placement) in &live {
                all_slots.extend(a.slots(&cfg, *ranks, *placement));
            }
            prop_assert!(
                RankMap::from_slots(&cfg, all_slots.clone()).is_ok(),
                "live placements collide or leave the machine: {} jobs",
                live.len()
            );
            let frag = rack.fragmentation();
            prop_assert!((0.0..=1.0).contains(&frag), "fragmentation {frag}");
        }
        Ok(())
    });
}

#[test]
fn prop_single_compact_job_matches_legacy_world_ps_exactly() {
    // Isolated-job parity: a lone job submitted through the scheduler
    // with Compact placement at offset 0 gets the legacy contiguous
    // RankMap, so its wall time must equal the direct contiguous-World
    // run to the picosecond — on both network models.
    use exanest::apps::scaling::{
        dims3, iteration_params, proxy_iteration, AppParams, HaloSchedule, Mode, ProxyAccum,
    };
    use exanest::mpi::collectives::Backend;
    use exanest::sched::{run_schedule, JobSpec, Policy, SchedConfig, Workload};
    let cfg = SystemConfig::two_blades();
    forall("single scheduled job == direct contiguous run (ps)", 6, |rng| {
        let ranks = [8usize, 12, 16][rng.below(3) as usize];
        let iters = 2usize;
        let model = if rng.below(2) == 0 {
            NetworkModel::Flow
        } else {
            NetworkModel::cell(RoutePolicy::Deterministic)
        };
        let app = AppParams::hpcg();
        let spec = JobSpec {
            name: "solo".to_string(),
            ranks,
            arrival: SimTime::ZERO,
            placement: Placement::PerCore,
            workload: Workload::Proxy { app: app.clone(), mode: Mode::Weak, iters },
        };
        let sc = SchedConfig::new(Policy::Compact, model.clone());
        let out = run_schedule(&cfg, &[spec], &sc).map_err(|e| e.to_string())?;
        prop_assert!(out.jobs.len() == 1, "one job scheduled");
        let sched_dur = out.jobs[0].finish - out.jobs[0].start;

        // direct run: the same iteration loop on a legacy contiguous world
        let mut w = World::with_model(cfg.clone(), ranks, Placement::PerCore, model);
        let group: Vec<usize> = (0..ranks).collect();
        let colocated = w.colocated(0).min(ranks);
        let (compute, face_bytes) = iteration_params(&app, Mode::Weak, ranks, colocated);
        let mut acc = ProxyAccum::default();
        let start = w.max_clock();
        for _ in 0..iters {
            proxy_iteration(
                &mut w,
                &group,
                dims3(ranks),
                compute,
                face_bytes,
                app.allreduces_per_iter,
                HaloSchedule::DimStaged,
                Backend::Software,
                &mut acc,
            );
        }
        let direct_dur = w.max_clock() - start;
        prop_assert!(
            sched_dur == direct_dur,
            "ranks={ranks}: scheduled {} ps != direct {} ps",
            sched_dur.0,
            direct_dur.0
        );
        // and the slowdown of a lone job is exactly 1
        prop_assert!(
            (out.jobs[0].slowdown - 1.0).abs() < 1e-12,
            "solo slowdown {}",
            out.jobs[0].slowdown
        );
        Ok(())
    });
}

#[test]
fn prop_concurrent_job_slowdown_at_least_one() {
    // occupancy-only contention can delay but never accelerate a job:
    // every job of a random two-job trace has slowdown >= 1 on both
    // network models
    use exanest::sched::{run_schedule, JobSpec, Policy, SchedConfig, Workload};
    let cfg = SystemConfig::two_blades();
    forall("concurrent jobs: slowdown >= 1", 6, |rng| {
        let policy =
            [Policy::Compact, Policy::BestFit, Policy::Scattered][rng.below(3) as usize];
        let model = if rng.below(2) == 0 {
            NetworkModel::Flow
        } else {
            NetworkModel::cell(RoutePolicy::Deterministic)
        };
        let mk = |name: &str, spec: &str, ranks: usize, arrival_us: f64| JobSpec {
            name: name.to_string(),
            ranks,
            arrival: SimTime::from_us(arrival_us),
            placement: Placement::PerCore,
            workload: Workload::by_spec(spec).expect("valid spec"),
        };
        let specs = [
            mk("a", "halo:hpcg:2", 16, 0.0),
            mk("b", "halo:minife:2", [8usize, 16][rng.below(2) as usize], 0.0),
        ];
        let sc = SchedConfig::new(policy, model);
        let out = run_schedule(&cfg, &specs, &sc).map_err(|e| e.to_string())?;
        for j in &out.jobs {
            prop_assert!(
                j.slowdown >= 1.0 - 1e-12,
                "{} under {:?}: slowdown {}",
                j.name,
                policy,
                j.slowdown
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Parallel DES (DESIGN.md §12): multi-worker execution must be a pure
// execution optimisation — every reported metric bit-identical to the
// single-threaded reference path at any worker count.

fn with_workers(cfg: &SystemConfig, workers: usize) -> SystemConfig {
    let mut c = cfg.clone();
    c.sim_workers = workers;
    c
}

#[test]
fn prop_parallel_hotspot_is_ps_exact() {
    // full-rack cell-level hotspot traffic (the congestion scenario):
    // per-pair and aggregate bandwidths identical at 1, 2 and 4 workers
    use exanest::apps::osu;
    let cfg = SystemConfig::rack();
    forall("hotspot: workers 1 == 2 == 4 (ps exact)", 4, |rng| {
        let bytes = [64 * 1024usize, 256 * 1024][rng.below(2) as usize];
        let window = 1 + rng.below(2) as usize;
        let policy = if rng.below(2) == 0 {
            RoutePolicy::Deterministic
        } else {
            RoutePolicy::Adaptive
        };
        let base = osu::osu_mbw_hotspot(&with_workers(&cfg, 1), policy, bytes, window);
        for workers in [2usize, 4] {
            let par =
                osu::osu_mbw_hotspot(&with_workers(&cfg, workers), policy, bytes, window);
            prop_assert!(
                par.aggregate_gbps == base.aggregate_gbps
                    && par.per_pair_gbps == base.per_pair_gbps,
                "{policy:?} {bytes} B x{window}: {workers} workers diverged \
                 ({:?} vs {:?} Gb/s)",
                par.per_pair_gbps,
                base.per_pair_gbps
            );
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_link_fault_incast_is_ps_exact() {
    // a torus link failure makes reroutes leave the minimal partition
    // box, so the runtime serializes every window (full mask) — results
    // must still be bit-identical across worker counts
    use exanest::apps::osu;
    let cfg = SystemConfig::rack();
    forall("incast failover: workers 1 == 4 under link faults", 3, |rng| {
        let bytes = 64 * 1024 * (1 + rng.below(3) as usize);
        let nsenders = 2 + rng.below(2) as usize;
        let (t1, g1) = osu::osu_incast_failover(&with_workers(&cfg, 1), nsenders, bytes);
        let (t4, g4) = osu::osu_incast_failover(&with_workers(&cfg, 4), nsenders, bytes);
        prop_assert!(
            t1 == t4 && g1 == g4,
            "{nsenders} senders x {bytes} B: workers 4 diverged \
             ({:?}/{g4} vs {:?}/{g1})",
            t4,
            t1
        );
        Ok(())
    });
}

#[test]
fn prop_parallel_rack_allreduce_is_ps_exact() {
    // the acceptance scenario's family: cell-level software allreduce on
    // the full rack, identical latency at 1, 2 and 4 workers
    use exanest::apps::osu;
    let cfg = SystemConfig::rack();
    let model = NetworkModel::cell(RoutePolicy::Deterministic);
    forall("rack allreduce: workers 1 == 2 == 4 (ps exact)", 3, |rng| {
        let n = [64usize, 256][rng.below(2) as usize];
        let bytes = [1024usize, 4096][rng.below(2) as usize];
        let base = osu::osu_allreduce_model(
            &with_workers(&cfg, 1),
            &model,
            n,
            bytes,
            1,
            Placement::PerCore,
        );
        for workers in [2usize, 4] {
            let t = osu::osu_allreduce_model(
                &with_workers(&cfg, workers),
                &model,
                n,
                bytes,
                1,
                Placement::PerCore,
            );
            prop_assert!(
                t == base,
                "{n} ranks x {bytes} B: {workers} workers gave {:?} vs {:?}",
                t,
                base
            );
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_sched_multi_job_is_ps_exact() {
    // `repro sched` traffic: concurrent jobs on one shared fabric — the
    // per-job interference numbers and the makespan are bit-identical
    // across worker counts
    use exanest::sched::{run_schedule, JobSpec, Policy, SchedConfig, Workload};
    let cfg = SystemConfig::two_blades();
    forall("sched multi-job: workers 1 == 2 (ps exact)", 3, |rng| {
        let policy =
            [Policy::Compact, Policy::BestFit, Policy::Scattered][rng.below(3) as usize];
        let mk = |name: &str, spec: &str, ranks: usize, arrival_us: f64| JobSpec {
            name: name.to_string(),
            ranks,
            arrival: SimTime::from_us(arrival_us),
            placement: Placement::PerCore,
            workload: Workload::by_spec(spec).expect("valid spec"),
        };
        let specs = [
            mk("halo", "halo:hpcg:2", 16, 0.0),
            mk("ar", "allreduce:1024x3", [8usize, 16][rng.below(2) as usize], 5.0),
        ];
        let sc1 = SchedConfig::new(policy, NetworkModel::Flow);
        let seq = run_schedule(&with_workers(&cfg, 1), &specs, &sc1).map_err(|e| e.to_string())?;
        let par = run_schedule(&with_workers(&cfg, 2), &specs, &sc1).map_err(|e| e.to_string())?;
        prop_assert!(
            seq.makespan_s == par.makespan_s,
            "{policy:?}: makespan {} vs {}",
            par.makespan_s,
            seq.makespan_s
        );
        for (a, b) in seq.jobs.iter().zip(&par.jobs) {
            prop_assert!(
                a.duration_s == b.duration_s && a.slowdown == b.slowdown,
                "{policy:?} job {}: {}s/{} vs {}s/{}",
                a.name,
                b.duration_s,
                b.slowdown,
                a.duration_s,
                a.slowdown
            );
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_world_reset_reruns_identically() {
    // Engine/runtime reset regression: after World::reset a multi-worker
    // world replays the same random traffic to identical clocks, and the
    // synchronizer counters restart from zero
    let base = SystemConfig::rack();
    forall("parallel world reset replays ps-exactly", 5, |rng| {
        let cfg = with_workers(&base, 4);
        let n = 32usize;
        let mut w = World::with_model(cfg, n, Placement::PerCore, NetworkModel::Flow);
        let ops: Vec<(usize, usize, usize)> = (0..12)
            .map(|_| {
                let src = rng.below(n as u64) as usize;
                let dst = (src + 1 + rng.below(n as u64 - 1) as usize) % n;
                (src, dst, 1 + rng.below(1 << 16) as usize)
            })
            .collect();
        let run = |w: &mut World| {
            let mut reqs = Vec::new();
            for &(src, dst, bytes) in &ops {
                reqs.push(progress::isend(w, src, dst, bytes));
                reqs.push(progress::irecv(w, dst, src, bytes));
            }
            progress::wait_all(w, &reqs);
            w.clocks.clone()
        };
        let first = run(&mut w);
        let stats = w.par_stats().expect("parallel runtime attached");
        prop_assert!(stats.ops > 0, "traffic must exercise the ledger");
        w.reset();
        let zeroed = w.par_stats().expect("parallel runtime attached");
        prop_assert!(
            zeroed.ops == 0 && zeroed.windows == 0,
            "reset must zero the synchronizer counters: {zeroed:?}"
        );
        let second = run(&mut w);
        prop_assert!(first == second, "replay diverged after reset");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Fault tolerance (DESIGN.md §14): transient faults and the reliable
// transport must never lose or duplicate a message, and fault plans must
// not perturb anything they don't touch.

#[test]
fn prop_flap_around_train_boundary_is_ps_exact_and_lossless() {
    // A link flap whose window lands on / inside / just after a cell
    // train must time identically on the batched fast path and the
    // per-cell event path (the mesh falls back to events near fault
    // transitions), and a flap alone never corrupts a cell — the mesh
    // reroutes around the down window, it does not drop.
    let cfg = SystemConfig::prototype();
    let topo = Topology::new(cfg.clone());
    forall("flap at train boundary: batched == events, zero loss", 20, |rng| {
        let nq = cfg.num_qfdbs() as u64;
        let victim = QfdbId(rng.below(nq) as u32);
        let dir = [Dir::XPlus, Dir::YMinus, Dir::ZPlus][rng.below(3) as usize];
        // windows from sub-cell widths to multi-train widths, placed
        // around the first block's injection time (t=0)
        let down = SimTime(rng.below(20_000_000)); // within the first ~20 us
        let up = down + SimDuration(1 + rng.below(30_000_000));
        let faults = FaultPlan::none().flap_torus(victim, dir, down, up);
        let policy = if rng.below(2) == 0 {
            RoutePolicy::Deterministic
        } else {
            RoutePolicy::Adaptive
        };
        let mut fast = RouterMesh::new(topo.clone(), policy, faults.clone());
        let mut slow = RouterMesh::new(topo.clone(), policy, faults);
        slow.set_batching(false);
        let n = cfg.num_mpsocs() as u64;
        let mut at = SimTime::ZERO;
        for k in 0..6 {
            let a = MpsocId(rng.below(n) as u32);
            let b = MpsocId(rng.below(n) as u32);
            if a == b {
                continue;
            }
            let bytes = [256usize, 4096, 64 * 1024][rng.below(3) as usize];
            let f = fast.block(a, b, at, bytes, false);
            let s = slow.block(a, b, at, bytes, false);
            prop_assert!(
                f == s,
                "call {k}: {a:?}->{b:?} {bytes} B at {at} across flap [{down}, {up}): \
                 batched {f:?} vs events {s:?}"
            );
            if rng.below(2) == 0 {
                at = f.0; // chain the next block into the flap window
            } else {
                at = at + SimDuration(rng.below(10_000_000));
            }
        }
        prop_assert!(
            fast.cells_corrupted() == 0 && slow.cells_corrupted() == 0,
            "a flap-only plan corrupted cells ({} batched / {} events)",
            fast.cells_corrupted(),
            slow.cells_corrupted()
        );
        Ok(())
    });
}

#[test]
fn prop_lossy_transport_is_live_exactly_once_and_never_faster() {
    // Seeded bit errors can hit any transport stage — eager payloads,
    // the RTS/CTS handshake, RDMA trains.  Every message must still be
    // delivered exactly once (waits return, the sequence check never
    // fires under timer-on-corruption, every corrupted launch is paid
    // for by exactly one retransmission), and retransmission can only
    // cost time: the lossy run is never faster than the clean one, and
    // ps-identical to it when no draw corrupted anything.
    let cfg = SystemConfig::two_blades();
    forall("BER transport: live, exactly-once, never faster", 12, |rng| {
        let ber = [1e-6, 1e-5, 1e-4][rng.below(3) as usize];
        let seed = rng.below(1 << 20);
        let n = 8usize;
        let mut clean = World::with_model(
            cfg.clone(),
            n,
            Placement::PerMpsoc,
            NetworkModel::cell(RoutePolicy::Deterministic),
        );
        let mut lossy = World::with_model(
            cfg.clone(),
            n,
            Placement::PerMpsoc,
            NetworkModel::cell_with_faults(
                RoutePolicy::Deterministic,
                FaultPlan::none().with_ber(ber, seed),
            ),
        );
        for k in 0..6 {
            let a = rng.below(n as u64) as usize;
            let mut b = rng.below(n as u64) as usize;
            if a == b {
                b = (b + 1) % n;
            }
            // eager (8/64), rendez-vous handshake + RDMA (4 KB, 64 KB)
            let bytes = [8usize, 64, 4096, 64 * 1024][rng.below(4) as usize];
            let c = pt2pt::send_recv(&mut clean, a, b, bytes);
            let l = pt2pt::send_recv(&mut lossy, a, b, bytes);
            prop_assert!(
                l.recv_done >= c.recv_done && l.send_done >= c.send_done,
                "msg {k} {a}->{b} {bytes} B: lossy ({:?}, {:?}) beat clean ({:?}, {:?})",
                l.send_done,
                l.recv_done,
                c.send_done,
                c.recv_done
            );
        }
        let (retx, drops, dups) = (
            lossy.progress.retransmissions(),
            lossy.progress.corrupt_drops(),
            lossy.progress.dup_drops(),
        );
        prop_assert!(
            dups == 0,
            "timer-on-corruption never duplicates, yet the sequence check dropped {dups}"
        );
        prop_assert!(
            retx == drops,
            "at quiescence every corrupted launch is retried exactly once: \
             {retx} retransmissions vs {drops} corrupted launches"
        );
        if drops == 0 {
            prop_assert!(
                lossy.clocks == clean.clocks && lossy.fabric.cells_corrupted() == 0,
                "zero corruption must leave the lossy run ps-identical"
            );
        } else {
            prop_assert!(
                lossy.fabric.cells_corrupted() > 0,
                "transport saw {drops} corrupted launches but the mesh corrupted no cell"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_lossy_run_is_worker_invariant() {
    // ISSUE acceptance: fault scenarios must report identical results at
    // every `--workers` count.  BER plans disable the parallel runtime
    // (the corruption draw is crossing-ordered), so a multi-worker
    // config must fall back to the reference path bit-for-bit.
    let base = SystemConfig::two_blades();
    forall("BER allreduce: workers 1 == 2 == 4", 6, |rng| {
        let bytes = [1024usize, 4096][rng.below(2) as usize];
        let n = [8usize, 16][rng.below(2) as usize];
        let seed = rng.below(1 << 20);
        let model = NetworkModel::cell_with_faults(
            RoutePolicy::Deterministic,
            FaultPlan::none().with_ber(1e-5, seed),
        );
        let mut runs = Vec::new();
        for workers in [1usize, 2, 4] {
            let mut w = World::with_model(
                with_workers(&base, workers),
                n,
                Placement::PerMpsoc,
                model.clone(),
            );
            let lat = exanest::mpi::collectives::allreduce(&mut w, bytes);
            prop_assert!(
                w.par_stats().is_none(),
                "w={workers}: lossy model must disable the parallel runtime"
            );
            runs.push((lat, w.clocks.clone(), w.progress.retransmissions()));
        }
        prop_assert!(
            runs[0] == runs[1] && runs[1] == runs[2],
            "lossy allreduce diverged across workers: {:?} / {:?} / {:?}",
            runs[0].0,
            runs[1].0,
            runs[2].0
        );
        Ok(())
    });
}
