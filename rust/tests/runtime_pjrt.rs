//! Runtime tests against the real AOT artifacts (requires `make artifacts`
//! and a real PJRT runtime).  In the offline build — no artifacts, or the
//! `xla` stub in place of the real crate — every test skips gracefully
//! instead of failing, so `cargo test` stays green without the toolchain.

use exanest::runtime::Executor;
use exanest::sim::Rng;

fn exec() -> Option<Executor> {
    match Executor::open_default() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping PJRT runtime test: {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    let Some(e) = exec() else { return };
    for name in [
        "matmul_tile128",
        "matmul_256",
        "matmul_512",
        "allreduce_sum_f32_64",
        "allreduce_min_f32_64",
        "allreduce_max_f32_64",
        "allreduce_sum_f64_32",
        "allreduce_sum_i32_64",
        "allreduce_sum_f32_1024",
        "cg_pre_8",
        "cg_post_8",
        "cg_update_p_8",
        "cg_pre_24",
        "cg_pre_48",
    ] {
        assert!(e.entry(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn matmul_tile_identity() {
    let Some(mut e) = exec() else { return };
    let n = 128;
    let mut eye = vec![0.0f32; n * n];
    for i in 0..n {
        eye[i * n + i] = 1.0;
    }
    let x: Vec<f32> = (0..n * n).map(|i| (i % 13) as f32 - 6.0).collect();
    let out = e.run_f32("matmul_tile128", &[&eye, &x]).unwrap();
    assert_eq!(out[0], x, "I @ X != X");
}

#[test]
fn allreduce_alu_ops() {
    let Some(mut e) = exec() else { return };
    let a: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..64).map(|i| 63.0 - i as f32).collect();
    let sum = e.run_f32("allreduce_sum_f32_64", &[&a, &b]).unwrap();
    assert!(sum[0].iter().all(|&v| v == 63.0));
    let mn = e.run_f32("allreduce_min_f32_64", &[&a, &b]).unwrap();
    assert_eq!(mn[0][0], 0.0);
    assert_eq!(mn[0][63], 0.0);
    let mx = e.run_f32("allreduce_max_f32_64", &[&a, &b]).unwrap();
    assert_eq!(mx[0][0], 63.0);
}

#[test]
fn allreduce_alu_int_and_double() {
    let Some(mut e) = exec() else { return };
    let ai: Vec<i32> = (0..64).collect();
    let bi: Vec<i32> = (0..64).map(|i| -i).collect();
    let s = e.run_i32("allreduce_sum_i32_64", &[&ai, &bi]).unwrap();
    assert!(s[0].iter().all(|&v| v == 0));
    let ad: Vec<f64> = (0..32).map(|i| i as f64 * 0.5).collect();
    let bd: Vec<f64> = (0..32).map(|i| i as f64 * 0.5).collect();
    let d = e.run_f64("allreduce_sum_f64_32", &[&ad, &bd]).unwrap();
    assert_eq!(d[0][31], 31.0);
}

#[test]
fn cg_pre_zero_input_is_zero() {
    let Some(mut e) = exec() else { return };
    let p = vec![0.0f32; 10 * 10 * 10];
    let out = e.run_f32("cg_pre_8", &[&p]).unwrap();
    assert!(out[0].iter().all(|&v| v == 0.0));
    assert_eq!(out[1][0], 0.0);
}

#[test]
fn cg_pre_matches_operator_definition() {
    // interior point of a constant field: 26*1 - 26*1 = 0;
    // corner of the local block with zero halo keeps 26 - 7 = 19
    let Some(mut e) = exec() else { return };
    let n = 8;
    let np = n + 2;
    let mut p = vec![0.0f32; np * np * np];
    for z in 1..=n {
        for y in 1..=n {
            for x in 1..=n {
                p[(z * np + y) * np + x] = 1.0;
            }
        }
    }
    let out = e.run_f32("cg_pre_8", &[&p]).unwrap();
    let interior = out[0][(4 * n + 4) * n + 4];
    assert!(interior.abs() < 1e-5, "interior {interior}");
    let corner = out[0][0];
    assert!((corner - 19.0).abs() < 1e-4, "corner {corner}");
}

#[test]
fn cg_post_and_update_do_axpy() {
    let Some(mut e) = exec() else { return };
    let n3 = 8 * 8 * 8;
    let x = vec![1.0f32; n3];
    let r = vec![2.0f32; n3];
    let p = vec![3.0f32; n3];
    let ap = vec![4.0f32; n3];
    let out = e.run_f32("cg_post_8", &[&x, &r, &p, &ap, &[0.5]]).unwrap();
    assert!(out[0].iter().all(|&v| (v - 2.5).abs() < 1e-6)); // x + 0.5 p
    assert!(out[1].iter().all(|&v| v.abs() < 1e-6)); // r - 0.5 ap = 0
    assert!((out[2][0] - 0.0).abs() < 1e-6);
    let upd = e.run_f32("cg_update_p_8", &[&r, &p, &[2.0]]).unwrap();
    assert!(upd[0].iter().all(|&v| (v - 8.0).abs() < 1e-6)); // r + 2 p
}

#[test]
fn rejects_bad_inputs() {
    let Some(mut e) = exec() else { return };
    let short = vec![0.0f32; 3];
    assert!(e.run_f32("matmul_tile128", &[&short, &short]).is_err());
    assert!(e.run_f32("nonexistent", &[&short]).is_err());
    let a = vec![0.0f32; 64];
    assert!(e.run_f32("allreduce_sum_f32_64", &[&a]).is_err(), "arity check");
}

#[test]
fn matmul_256_matches_naive() {
    let Some(mut e) = exec() else { return };
    let mut rng = Rng::new(5);
    let n = 256;
    let a = rng.f32_vec(n * n);
    let b = rng.f32_vec(n * n);
    let got = e.run_f32("matmul_256", &[&a, &b]).unwrap();
    // spot-check a handful of entries against a naive dot product
    for &(i, j) in &[(0usize, 0usize), (13, 200), (255, 255), (100, 7)] {
        let want: f32 = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
        let g = got[0][i * n + j];
        assert!((g - want).abs() < 1e-2, "({i},{j}): {g} vs {want}");
    }
}
