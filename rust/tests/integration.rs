//! Cross-module integration tests: the whole simulated machine exercised
//! end to end (fabric + NI + MPI + collectives + apps + accelerators).

use exanest::accel::AccelAllreduce;
use exanest::apps::osu::{self, OsuPath};
use exanest::apps::scaling::{scaling_curve, AppParams, Mode};
use exanest::ip::{iperf, IpMode, Scenario, TunnelConfig};
use exanest::model;
use exanest::mpi::{collectives, pt2pt, Placement, World};
use exanest::network::{NetworkModel, RoutePolicy};
use exanest::topology::SystemConfig;

fn cfg() -> SystemConfig {
    SystemConfig::prototype()
}

#[test]
fn paper_headline_numbers() {
    // The abstract's numbers, in one test:
    // single-hop one-way 1.3 us; ~0.47 us NI+library; 2.55 us at 5 hops;
    // 82% link utilisation; allreduce accelerator up to 88%; efficiency
    // >= 69% everywhere.
    let c = cfg();
    let l1 = osu::osu_latency(&c, OsuPath::IntraQfdbSh, 0, 50).us();
    assert!((l1 - 1.3).abs() < 0.1, "single-hop {l1}");
    let l5 = osu::osu_latency(&c, OsuPath::InterMezz312, 0, 50).us();
    assert!((l5 - 2.55).abs() < 0.35, "five-hop {l5}");
    let mut fab = exanest::network::Fabric::new(c.clone());
    let a = fab.topo.mpsoc(0, 0, 0);
    let b = fab.topo.mpsoc(0, 0, 1);
    let hw = exanest::ni::hw_pingpong(&mut fab, a, b, 1000).ns();
    assert!((hw - 470.0).abs() < 40.0, "hw ping-pong {hw}");
    let util = osu::osu_bw(&c, OsuPath::IntraQfdbSh, 4 << 20, 64) / 16.0;
    assert!((util - 0.819).abs() < 0.03, "link utilisation {util}");
}

#[test]
fn network_models_agree_on_table2_at_zero_load() {
    // The whole MPI stack (progress engine, eager protocol, OSU harness)
    // over the cell-level router mesh must land on the flow model's
    // numbers for every Table-2 path class when nothing contends.
    let c = cfg();
    let model = NetworkModel::cell(RoutePolicy::Deterministic);
    for path in OsuPath::ALL {
        let flow = osu::osu_latency(&c, path, 0, 20).us();
        let cell = osu::osu_latency_model(&c, &model, path, 0, 20).us();
        assert!(
            (cell - flow).abs() / flow < 0.01,
            "{}: cell-level {cell} vs flow {flow}",
            path.label()
        );
    }
}

#[test]
fn cell_level_full_machine_collectives_run() {
    // A 64-rank broadcast entirely on the router mesh: completes, stays in
    // a sane envelope, and a barrier after reset still works (mesh reset
    // path through World::reset).
    let mut w = World::with_model(
        SystemConfig::two_blades(),
        64,
        Placement::PerCore,
        NetworkModel::cell(RoutePolicy::Adaptive),
    );
    let b = collectives::bcast(&mut w, 64);
    assert!(b.us() > 1.0 && b.us() < 100.0, "cell-level bcast {b}");
    w.reset();
    let bar = collectives::barrier(&mut w);
    assert!(bar.us() > 1.0 && bar.us() < 100.0, "cell-level barrier {bar}");
}

#[test]
fn full_machine_barrier_and_collectives() {
    let mut w = World::new(cfg(), 512, Placement::PerCore);
    let b = collectives::barrier(&mut w);
    assert!(b.us() > 2.0 && b.us() < 100.0, "barrier {b}");
    w.reset();
    let g = collectives::gather(&mut w, 64);
    assert!(g.us() > 5.0, "gather {g}");
    w.reset();
    let ag = collectives::allgather(&mut w, 64);
    assert!(ag > g, "allgather {ag} should exceed gather {g}");
}

#[test]
fn every_table1_class_reachable_and_ordered() {
    let c = cfg();
    let mut last = 0.0;
    for p in OsuPath::ALL {
        let lat = osu::osu_latency(&c, p, 0, 20).us();
        assert!(lat > last, "{}: {lat} not > {last}", p.label());
        last = lat;
    }
}

#[test]
fn rendezvous_and_eager_consistent_across_machine() {
    // send_recv between all pairs of a sample must be finite, positive,
    // and larger for bigger payloads
    let mut w = World::new(cfg(), 512, Placement::PerCore);
    for &dst in &[1usize, 5, 77, 311, 511] {
        let e = pt2pt::send_recv(&mut w, 0, dst, 8);
        w.reset();
        let r = pt2pt::send_recv(&mut w, 0, dst, 64 * 1024);
        assert!(r.recv_done > e.recv_done, "dst {dst}");
        w.reset();
    }
}

#[test]
fn accelerator_beats_software_for_fig19_range() {
    let c = cfg();
    for n in [16usize, 32, 64, 128] {
        for s in [4usize, 256, 1024, 4096] {
            let sw = osu::osu_allreduce(&c, n, s, 3, Placement::PerMpsoc);
            let mut w = World::new(c.clone(), n, Placement::PerMpsoc);
            let hw = AccelAllreduce::latency(&mut w, s);
            assert!(hw < sw, "{n} ranks {s} B: hw {hw} vs sw {sw}");
        }
    }
}

#[test]
fn accelerator_improvement_is_paper_magnitude() {
    // paper: max improvement 83.4-87.9% over the four rank counts
    let c = cfg();
    for n in [16usize, 32, 64, 128] {
        let mut best = 0.0f64;
        for s in [256usize, 1024, 4096] {
            let sw = osu::osu_allreduce(&c, n, s, 3, Placement::PerMpsoc);
            let mut w = World::new(c.clone(), n, Placement::PerMpsoc);
            let hw = AccelAllreduce::latency(&mut w, s);
            best = best.max(1.0 - hw.ns() / sw.ns());
        }
        assert!(best > 0.80 && best < 0.97, "{n} ranks: improvement {best}");
    }
}

#[test]
fn ip_overlay_reproduces_fig13_shape() {
    let tc = TunnelConfig::default();
    for s in Scenario::ALL {
        assert!(iperf(&tc, s, IpMode::Overlay, 5) > iperf(&tc, s, IpMode::Baseline, 5));
    }
}

#[test]
fn eq1_model_inputs_match_measurements() {
    let c = cfg();
    let lats = model::one_way_lats(&c, 1);
    assert!(lats.mpsoc < lats.qfdb && lats.qfdb < lats.mezz);
}

#[test]
fn scaling_curves_are_complete_and_sane() {
    let c = cfg();
    let app = AppParams::hpcg();
    let pts = scaling_curve(&c, &app, Mode::Weak, &[1, 2, 4, 8]).unwrap();
    assert_eq!(pts.len(), 4);
    assert!((pts[0].efficiency - 1.0).abs() < 1e-9, "1-rank eff must be 1.0");
    for p in &pts {
        assert!(p.time_s > 0.0 && p.comm_fraction < 0.6);
        assert!((0.0..1.0).contains(&p.overlap_fraction));
    }
}

#[test]
fn full_stack_proxy_app_on_cell_mesh_with_accel_dispatch() {
    // The first end-to-end run of the whole stack on one workload:
    // timing-wheel engine → cell-level torus routers → NI protocol →
    // nonblocking MPI → event-driven proxy app, with dot products
    // dispatched to the in-NI accelerator.
    use exanest::apps::scaling::{run_point, ProxyConfig};
    use exanest::mpi::Backend;
    let c = SystemConfig::two_blades();
    let app = AppParams::minife();
    let proxy = ProxyConfig {
        model: NetworkModel::cell(RoutePolicy::Deterministic),
        backend: Backend::Accel,
        ..ProxyConfig::default()
    };
    let m = run_point(&c, &app, 16, Mode::Weak, &proxy);
    assert!(m.time_s > 0.0);
    assert_eq!(m.backend, Backend::Accel, "16 ranks on 8 QFDBs satisfy §4.7");
    assert!(m.comm_fraction > 0.0 && m.comm_fraction < 1.0);
}

#[test]
fn mezzanine_testbed_also_works() {
    // the smaller air-cooled subsystem: 1 mezzanine, 4 QFDBs
    let c = SystemConfig::mezzanine();
    let mut w = World::new(c, 64, Placement::PerCore);
    let lat = collectives::bcast(&mut w, 1);
    assert!(lat.us() > 1.0 && lat.us() < 50.0, "{lat}");
}

#[test]
fn scheduler_end_to_end_trace_on_shared_cell_mesh() {
    // The multi-tenant path end to end: trace parsing → FCFS admission
    // under a placement policy → concurrent jobs on one shared
    // cell-level fabric → interference metrics.
    use exanest::sched::{parse_trace, run_schedule, Policy, SchedConfig};
    let c = SystemConfig::two_blades();
    let specs = parse_trace(
        "a halo:hpcg:2 16 0\n\
         b halo:minife:2 16 0\n\
         c allreduce:1024x2 8 200\n",
    )
    .unwrap();
    let sc = SchedConfig::new(Policy::Scattered, NetworkModel::cell(RoutePolicy::Deterministic));
    let out = run_schedule(&c, &specs, &sc).unwrap();
    assert_eq!(out.jobs.len(), 3);
    for j in &out.jobs {
        assert!(j.slowdown >= 1.0 - 1e-12, "{}: slowdown {}", j.name, j.slowdown);
        assert!(j.duration_s > 0.0 && j.isolated_s > 0.0);
    }
    assert!(out.makespan_s > 0.0);
    assert!((0.0..=1.0).contains(&out.utilization));
    assert!(out.power_peak_w >= out.power_avg_w);
}

#[test]
fn traced_allreduce_covers_the_run_and_exports_valid_chrome_json() {
    // The observability acceptance scenario: a full osu-style allreduce
    // on the two-blade cell model with the flight recorder on.  The
    // rank-track spans must cover >= 95% of the end-to-end latency, and
    // the Chrome trace-event export must be structurally valid with the
    // metadata Perfetto needs (scripts/trace_check.py deepens this with
    // a real JSON parse in CI).
    use exanest::telemetry::{self, Track};
    let c = SystemConfig::two_blades();
    let mut w = World::with_model(
        c,
        8,
        Placement::PerCore,
        NetworkModel::cell(RoutePolicy::Deterministic),
    );
    w.enable_tracing(1 << 18);
    let lat = collectives::allreduce(&mut w, 4096);
    assert!(lat.ns() > 0.0);
    w.fabric.sample_telemetry(w.max_clock());
    let recs = w.trace_records();
    assert!(!recs.is_empty());
    assert_eq!(w.trace_dropped(), 0, "capacity must hold the scenario");
    // union of rank-track spans vs the simulated horizon
    let mut iv: Vec<(u64, u64)> = recs
        .iter()
        .filter(|r| matches!(r.track, Track::Rank(_)))
        .map(|r| (r.t0.0, r.t1.0))
        .collect();
    iv.sort_unstable();
    let mut covered = 0u64;
    let mut end = 0u64;
    for (a, b) in iv {
        if b > end {
            covered += b - a.max(end);
            end = b;
        }
    }
    let total = w.max_clock().0;
    assert!(total > 0);
    let cover = covered as f64 / total as f64;
    assert!(cover >= 0.95, "rank spans cover {cover:.3} of the run");
    // export: Chrome trace JSON + series CSV
    let json = telemetry::chrome_trace_json(&recs, w.trace_dropped());
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"mpi-ranks\""));
    assert!(json.contains(&format!("\"records\": {}", recs.len())));
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    assert_eq!(json.matches('[').count(), json.matches(']').count());
    let csv = telemetry::series_csv(w.fabric.telemetry());
    assert!(csv.lines().count() >= 2, "header + at least one window: {csv}");
    // the heatmap renders every z-plane of the two-blade torus
    let heat = telemetry::torus_heatmap(
        &w.fabric,
        exanest::sim::SimDuration(w.max_clock().0),
    );
    assert!(heat.contains("z=0"), "{heat}");
}

#[test]
fn ni_plus_library_spans_sum_to_the_paper_0_47_us() {
    // REPRODUCING.md's span-query check: for one eager message (32 B is
    // the eager/rendez-vous switch point), the sender-side library span
    // (mpi_sw) plus the NI hand-off span (doorbell/descriptor write)
    // reproduce the paper's ~0.47 us NI+library share of the single-hop
    // latency.
    use exanest::mpi::progress;
    use exanest::telemetry::SpanKind;
    let c = SystemConfig::two_blades();
    let mut w = World::new(c, 2, Placement::PerCore);
    w.enable_tracing(1024);
    let s = progress::isend(&mut w, 0, 1, 32);
    let r = progress::irecv(&mut w, 1, 0, 32);
    progress::wait_all(&mut w, &[s, r]);
    let recs = w.trace_records();
    let dur = |k: SpanKind| -> u64 {
        recs.iter().filter(|x| x.kind == k).map(|x| x.t1.0 - x.t0.0).sum()
    };
    let (lib, ni) = (dur(SpanKind::Lib), dur(SpanKind::Ni));
    assert!(lib > 0, "missing library span");
    assert!(ni > 0, "missing NI span");
    let sum_ns = (lib + ni) as f64 / 1000.0;
    assert!(
        (sum_ns - 470.0).abs() < 40.0,
        "NI+library span sum {sum_ns} ns (paper ~470 ns)"
    );
}
