//! CLI-level tests driving the built `repro` binary: the strict-parser
//! error matrix (every malformed flag exits 2 and names the offender)
//! and the seeded-determinism regression (same subcommand + flags twice
//! ⇒ byte-identical BENCH JSON metrics).

use std::path::PathBuf;
use std::process::{Command, Output};

fn repro(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .output()
        .expect("spawn repro")
}

fn repro_bench(args: &[&str], dir: &std::path::Path) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .env("BENCH_JSON_DIR", dir)
        .output()
        .expect("spawn repro")
}

/// Assert `repro args` is rejected as a usage error (exit 2) and that
/// the diagnostic names the offending flag, not some generic panic.
fn assert_usage_error(args: &[&str], names: &str) {
    let out = repro(args);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(
        out.status.code(),
        Some(2),
        "`repro {}` must exit 2, got {:?}\nstderr: {stderr}",
        args.join(" "),
        out.status.code()
    );
    assert!(
        stderr.contains(names),
        "`repro {}` stderr must name {names:?}:\n{stderr}",
        args.join(" ")
    );
}

#[test]
fn unknown_flag_is_rejected_by_every_subcommand() {
    // `Args::finish` runs before any subcommand does real work, so this
    // matrix is cheap: each spawn dies at argument parsing.
    let cmds = [
        "table1",
        "hw-pingpong",
        "osu-latency",
        "osu-bw",
        "osu-bcast",
        "osu-allreduce",
        "osu-mbw",
        "osu-incast",
        "osu-overlap",
        "router-hotspot",
        "faults",
        "qos",
        "blame",
        "bcast-model",
        "allreduce-accel",
        "scaling",
        "sched",
        "ip-overlay",
        "matmul-accel",
        "all",
    ];
    for cmd in cmds {
        assert_usage_error(&[cmd, "--bogus"], "--bogus");
        assert_usage_error(&[cmd, "--bidirektional"], "--bidirektional");
    }
}

#[test]
fn unknown_subcommand_prints_usage() {
    let out = repro(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage: repro"), "usage text expected:\n{stderr}");
}

#[test]
fn malformed_global_flags_exit_2_naming_the_flag() {
    assert_usage_error(&["osu-latency", "--network-model", "sideways"], "unknown network model");
    assert_usage_error(&["sched", "--workers", "0"], "--workers");
    assert_usage_error(&["sched", "--workers", "many"], "--workers");
    assert_usage_error(&["table1", "--small", "--rack"], "--small and --rack");
    // --small only covers the scenarios that fit two blades
    assert_usage_error(&["table1", "--small"], "--small");
    // a value flag with its value missing
    assert_usage_error(&["sched", "--policy"], "--policy needs a value");
    // observability flags outside the traceable commands
    assert_usage_error(&["table1", "--telemetry"], "--trace/--telemetry apply to");
}

#[test]
fn malformed_fault_flags_exit_2_naming_the_flag() {
    // fault flags demand a cell-level model up front
    assert_usage_error(&["sched", "--ber", "1e-6"], "--faults/--flap/--ber need a cell-level");
    // malformed values behind a valid model
    assert_usage_error(
        &["sched", "--network-model", "cell", "--ber", "garbage"],
        "bad bit-error rate",
    );
    assert_usage_error(
        &["sched", "--network-model", "cell", "--flap", "0:x+:50"],
        "bad --flap item",
    );
    assert_usage_error(
        &["sched", "--network-model", "cell", "--faults", "0:q+:50"],
        "bad torus direction",
    );
    assert_usage_error(
        &["sched", "--network-model", "cell", "--faults", "9999:x+:50"],
        "out of range",
    );
}

#[test]
fn malformed_qos_flags_exit_2_naming_the_flag() {
    // QoS flags only apply where traffic classes exist
    assert_usage_error(&["table1", "--qos"], "--qos");
    assert_usage_error(&["osu-latency", "--qos-weights", "4,1,1,1"], "--qos");
    // wrong arity, non-numeric and zero weights
    assert_usage_error(&["qos", "--qos-weights", "garbage"], "--qos-weights");
    assert_usage_error(&["qos", "--qos-weights", "1,2,3"], "--qos-weights");
    assert_usage_error(&["qos", "--qos-weights", "1,2,3,oops"], "--qos-weights");
    assert_usage_error(&["qos", "--qos-weights", "1,2,3,0"], "--qos-weights");
    // malformed window / mark threshold
    assert_usage_error(&["qos", "--qos-window", "lots"], "--qos-window");
    assert_usage_error(&["qos", "--qos-mark", "-1"], "--qos-mark");
}

/// Pull the `"metrics":[...]` array out of a BENCH JSON file: the
/// deterministic payload (provenance keys like `config_hash` legitimately
/// change with `--workers`).
fn metrics_of(path: &PathBuf) -> String {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path:?}: {e}"));
    text.split("\"metrics\":[")
        .nth(1)
        .and_then(|rest| rest.split("\n]").next())
        .unwrap_or_else(|| panic!("no metrics array in {path:?}"))
        .to_string()
}

fn run_to_dir(args: &[&str], tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("exanest_cli_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let out = repro_bench(args, &dir);
    assert!(
        out.status.success(),
        "`repro {}` failed: {}",
        args.join(" "),
        String::from_utf8_lossy(&out.stderr)
    );
    dir
}

#[test]
fn blame_cmd_prints_decomposition_and_critical_path() {
    // The blame engine's CLI surface: a traced two-blade allreduce must
    // decompose (the command itself asserts the ps-exact partition per
    // message and aborts on violation), extract a critical path, and
    // report the §6.1.1 lib+NI hand-off share near the paper's 0.47 us.
    let dir = std::env::temp_dir().join("exanest_cli_blame");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("blame_trace.json");
    let out = repro_bench(
        &["blame", "--small", "--trace", trace.to_str().unwrap()],
        &dir,
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "`repro blame --small` failed: {}\n{stdout}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("blame decomposition"), "missing decomposition:\n{stdout}");
    assert!(stdout.contains("critical path"), "missing critical path:\n{stdout}");
    assert!(stdout.contains("straggler"), "missing straggler line:\n{stdout}");
    // the lib+NI anchor, parsed from the summary line
    let share = stdout
        .lines()
        .find(|l| l.contains("mean sender lib+NI hand-off"))
        .and_then(|l| l.split("hand-off ").nth(1))
        .and_then(|rest| rest.split(" us").next())
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or_else(|| panic!("no lib+NI summary line:\n{stdout}"));
    assert!(
        (share - 0.47).abs() <= 0.04,
        "lib+NI hand-off share {share} us is not within 40 ns of the paper's 0.47 us"
    );
    // the exported trace carries the critical-path lane
    let json = std::fs::read_to_string(&trace).expect("trace written");
    assert!(json.contains("critical-path"), "trace lacks the critical-path process");
    assert!(json.contains("crit-edge"), "trace lacks CritEdge spans");
    // BENCH_blame.json carries the blame shares
    let bench = std::fs::read_to_string(dir.join("BENCH_blame.json")).expect("bench written");
    assert!(bench.contains("\"name\":\"blame/lib_us\""), "bench lacks blame metrics");
    assert!(bench.contains("\"name\":\"lib_ni_us\""));
}

#[test]
fn blame_bench_json_is_deterministic_across_runs() {
    let a = run_to_dir(&["blame", "--small"], "blame_det_a");
    let b = run_to_dir(&["blame", "--small"], "blame_det_b");
    let ma = metrics_of(&a.join("BENCH_blame.json"));
    let mb = metrics_of(&b.join("BENCH_blame.json"));
    assert!(ma.contains("lib_ni_us"), "metrics missing: {ma}");
    assert_eq!(ma, mb, "repro blame --small is not run-to-run deterministic");
}

#[test]
fn sched_bench_json_is_deterministic_across_runs() {
    // The seeded-determinism regression: the same subcommand with the
    // same flags must write byte-identical BENCH metric values — no
    // wall-clock or iteration noise leaks into the tracked numbers.
    let a = run_to_dir(&["sched", "--small"], "sched_det_a");
    let b = run_to_dir(&["sched", "--small"], "sched_det_b");
    let ma = metrics_of(&a.join("BENCH_sched.json"));
    let mb = metrics_of(&b.join("BENCH_sched.json"));
    assert!(!ma.is_empty() && ma.contains("makespan_s"), "metrics missing: {ma}");
    assert_eq!(ma, mb, "repro sched --small is not run-to-run deterministic");
}

#[test]
fn qos_bench_json_is_deterministic_and_worker_invariant() {
    // Twice with identical flags: byte-identical metrics.  Then at
    // --workers 4: still identical metrics (worker count is a pure
    // execution knob; only the config fingerprint may differ).
    let a = run_to_dir(&["qos", "--small"], "qos_det_a");
    let b = run_to_dir(&["qos", "--small"], "qos_det_b");
    let ma = metrics_of(&a.join("BENCH_qos.json"));
    let mb = metrics_of(&b.join("BENCH_qos.json"));
    assert!(
        ma.contains("scenario/incast-bully/isolation_gain"),
        "qos suite must stamp per-scenario metrics: {ma}"
    );
    assert_eq!(ma, mb, "repro qos --small is not run-to-run deterministic");
    let w4 = run_to_dir(&["qos", "--small", "--workers", "4"], "qos_det_w4");
    let mw = metrics_of(&w4.join("BENCH_qos.json"));
    assert_eq!(ma, mw, "repro qos --small diverges at --workers 4");
}
