//! Table-2 golden-fixture test: the paper's per-path one-way latencies
//! and the 81.9% link-utilisation headline live in
//! `tests/fixtures/table2.json`, and both network models must land
//! inside the fixture's tolerances.  Changing the fixture is an explicit
//! act — a timing regression cannot silently re-baseline itself.

use exanest::apps::osu::{self, OsuPath};
use exanest::network::{NetworkModel, RoutePolicy};
use exanest::topology::SystemConfig;

const FIXTURE: &str = include_str!("fixtures/table2.json");

/// Extract `"key": <number>` from the fixture (no JSON dependency in the
/// offline vendor set — the fixture is flat, so field scraping is exact).
fn field(key: &str) -> f64 {
    let tag = format!("\"{key}\":");
    let rest = FIXTURE.split(&tag).nth(1).unwrap_or_else(|| panic!("fixture lacks {key}"));
    let end = rest.find(&[',', '\n', '}'][..]).unwrap();
    rest[..end].trim().parse().unwrap_or_else(|e| panic!("bad number for {key}: {e}"))
}

/// Extract the `"paths_us": [...]` anchor array.
fn paths_us() -> Vec<f64> {
    let rest = FIXTURE.split("\"paths_us\":").nth(1).expect("fixture lacks paths_us");
    let open = rest.find('[').unwrap();
    let close = rest.find(']').unwrap();
    rest[open + 1..close]
        .split(',')
        .map(|s| s.trim().parse().expect("bad anchor"))
        .collect()
}

#[test]
fn fixture_is_well_formed() {
    let anchors = paths_us();
    assert_eq!(anchors.len(), OsuPath::ALL.len(), "one anchor per Table-2 path class");
    assert!(anchors.windows(2).all(|w| w[0] < w[1]), "anchors must grow with path length");
    assert!(field("latency_tolerance_frac") > 0.0);
    assert!((0.0..1.0).contains(&field("util_frac")));
}

#[test]
fn table2_latencies_match_the_fixture_on_both_models() {
    let cfg = SystemConfig::prototype();
    let anchors = paths_us();
    let tol = field("latency_tolerance_frac");
    let models = [
        ("flow", NetworkModel::Flow),
        ("cell", NetworkModel::cell(RoutePolicy::Deterministic)),
    ];
    for (label, model) in models {
        for (path, want) in OsuPath::ALL.iter().zip(&anchors) {
            let got = osu::osu_latency_model(&cfg, &model, *path, 0, 50).us();
            let rel = (got - want).abs() / want;
            assert!(
                rel <= tol,
                "{label} {}: {got:.3} us vs golden {want:.3} us ({:.1}% off, tol {:.0}%)",
                path.label(),
                rel * 100.0,
                tol * 100.0
            );
        }
    }
}

#[test]
fn link_utilisation_matches_the_fixture() {
    // The 82%-of-16-Gb/s headline at 4 MiB with a 64-message window on
    // the flow model (`network_models_agree_on_table2_at_zero_load` in
    // `integration.rs` pins the cell model to the flow model separately).
    let cfg = SystemConfig::prototype();
    let util =
        osu::osu_bw_model(&cfg, &NetworkModel::Flow, OsuPath::IntraQfdbSh, 4 << 20, 64) / 16.0;
    let want = field("util_frac");
    let tol = field("util_tolerance_abs");
    assert!(
        (util - want).abs() <= tol,
        "intra-QFDB utilisation {util:.4} vs golden {want:.4} (tol ±{tol}))"
    );
}
