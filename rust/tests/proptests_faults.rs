//! Property tests over fault tolerance (DESIGN.md §14): transient
//! faults and the reliable transport must never lose or duplicate a
//! message, and fault plans must not perturb anything they don't touch.
//! Shared harness: `exanest::testing`.

use exanest::mpi::{pt2pt, Placement, World};
use exanest::network::{FaultPlan, NetworkModel, RoutePolicy, RouterMesh};
use exanest::prop_assert;
use exanest::sim::{SimDuration, SimTime};
use exanest::testing::{forall, with_workers};
use exanest::topology::{Dir, MpsocId, QfdbId, SystemConfig, Topology};

#[test]
fn prop_flap_around_train_boundary_is_ps_exact_and_lossless() {
    // A link flap whose window lands on / inside / just after a cell
    // train must time identically on the batched fast path and the
    // per-cell event path (the mesh falls back to events near fault
    // transitions), and a flap alone never corrupts a cell — the mesh
    // reroutes around the down window, it does not drop.
    let cfg = SystemConfig::prototype();
    let topo = Topology::new(cfg.clone());
    forall("flap at train boundary: batched == events, zero loss", 20, |rng| {
        let nq = cfg.num_qfdbs() as u64;
        let victim = QfdbId(rng.below(nq) as u32);
        let dir = [Dir::XPlus, Dir::YMinus, Dir::ZPlus][rng.below(3) as usize];
        // windows from sub-cell widths to multi-train widths, placed
        // around the first block's injection time (t=0)
        let down = SimTime(rng.below(20_000_000)); // within the first ~20 us
        let up = down + SimDuration(1 + rng.below(30_000_000));
        let faults = FaultPlan::none().flap_torus(victim, dir, down, up);
        let policy = if rng.below(2) == 0 {
            RoutePolicy::Deterministic
        } else {
            RoutePolicy::Adaptive
        };
        let mut fast = RouterMesh::new(topo.clone(), policy, faults.clone());
        let mut slow = RouterMesh::new(topo.clone(), policy, faults);
        slow.set_batching(false);
        let n = cfg.num_mpsocs() as u64;
        let mut at = SimTime::ZERO;
        for k in 0..6 {
            let a = MpsocId(rng.below(n) as u32);
            let b = MpsocId(rng.below(n) as u32);
            if a == b {
                continue;
            }
            let bytes = [256usize, 4096, 64 * 1024][rng.below(3) as usize];
            let f = fast.block(a, b, at, bytes, false);
            let s = slow.block(a, b, at, bytes, false);
            prop_assert!(
                f == s,
                "call {k}: {a:?}->{b:?} {bytes} B at {at} across flap [{down}, {up}): \
                 batched {f:?} vs events {s:?}"
            );
            if rng.below(2) == 0 {
                at = f.0; // chain the next block into the flap window
            } else {
                at = at + SimDuration(rng.below(10_000_000));
            }
        }
        prop_assert!(
            fast.cells_corrupted() == 0 && slow.cells_corrupted() == 0,
            "a flap-only plan corrupted cells ({} batched / {} events)",
            fast.cells_corrupted(),
            slow.cells_corrupted()
        );
        Ok(())
    });
}

#[test]
fn prop_lossy_transport_is_live_exactly_once_and_never_faster() {
    // Seeded bit errors can hit any transport stage — eager payloads,
    // the RTS/CTS handshake, RDMA trains.  Every message must still be
    // delivered exactly once (waits return, the sequence check never
    // fires under timer-on-corruption, every corrupted launch is paid
    // for by exactly one retransmission), and retransmission can only
    // cost time: the lossy run is never faster than the clean one, and
    // ps-identical to it when no draw corrupted anything.
    let cfg = SystemConfig::two_blades();
    forall("BER transport: live, exactly-once, never faster", 12, |rng| {
        let ber = [1e-6, 1e-5, 1e-4][rng.below(3) as usize];
        let seed = rng.below(1 << 20);
        let n = 8usize;
        let mut clean = World::with_model(
            cfg.clone(),
            n,
            Placement::PerMpsoc,
            NetworkModel::cell(RoutePolicy::Deterministic),
        );
        let mut lossy = World::with_model(
            cfg.clone(),
            n,
            Placement::PerMpsoc,
            NetworkModel::cell_with_faults(
                RoutePolicy::Deterministic,
                FaultPlan::none().with_ber(ber, seed),
            ),
        );
        for k in 0..6 {
            let a = rng.below(n as u64) as usize;
            let mut b = rng.below(n as u64) as usize;
            if a == b {
                b = (b + 1) % n;
            }
            // eager (8/64), rendez-vous handshake + RDMA (4 KB, 64 KB)
            let bytes = [8usize, 64, 4096, 64 * 1024][rng.below(4) as usize];
            let c = pt2pt::send_recv(&mut clean, a, b, bytes);
            let l = pt2pt::send_recv(&mut lossy, a, b, bytes);
            prop_assert!(
                l.recv_done >= c.recv_done && l.send_done >= c.send_done,
                "msg {k} {a}->{b} {bytes} B: lossy ({:?}, {:?}) beat clean ({:?}, {:?})",
                l.send_done,
                l.recv_done,
                c.send_done,
                c.recv_done
            );
        }
        let (retx, drops, dups) = (
            lossy.progress.retransmissions(),
            lossy.progress.corrupt_drops(),
            lossy.progress.dup_drops(),
        );
        prop_assert!(
            dups == 0,
            "timer-on-corruption never duplicates, yet the sequence check dropped {dups}"
        );
        prop_assert!(
            retx == drops,
            "at quiescence every corrupted launch is retried exactly once: \
             {retx} retransmissions vs {drops} corrupted launches"
        );
        if drops == 0 {
            prop_assert!(
                lossy.clocks == clean.clocks && lossy.fabric.cells_corrupted() == 0,
                "zero corruption must leave the lossy run ps-identical"
            );
        } else {
            prop_assert!(
                lossy.fabric.cells_corrupted() > 0,
                "transport saw {drops} corrupted launches but the mesh corrupted no cell"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_lossy_run_is_worker_invariant() {
    // Fault scenarios must report identical results at every `--workers`
    // count.  BER plans disable the parallel runtime (the corruption
    // draw is crossing-ordered), so a multi-worker config must fall back
    // to the reference path bit-for-bit.
    let base = SystemConfig::two_blades();
    forall("BER allreduce: workers 1 == 2 == 4", 6, |rng| {
        let bytes = [1024usize, 4096][rng.below(2) as usize];
        let n = [8usize, 16][rng.below(2) as usize];
        let seed = rng.below(1 << 20);
        let model = NetworkModel::cell_with_faults(
            RoutePolicy::Deterministic,
            FaultPlan::none().with_ber(1e-5, seed),
        );
        let mut runs = Vec::new();
        for workers in [1usize, 2, 4] {
            let mut w = World::with_model(
                with_workers(&base, workers),
                n,
                Placement::PerMpsoc,
                model.clone(),
            );
            let lat = exanest::mpi::collectives::allreduce(&mut w, bytes);
            prop_assert!(
                w.par_stats().is_none(),
                "w={workers}: lossy model must disable the parallel runtime"
            );
            runs.push((lat, w.clocks.clone(), w.progress.retransmissions()));
        }
        prop_assert!(
            runs[0] == runs[1] && runs[1] == runs[2],
            "lossy allreduce diverged across workers: {:?} / {:?} / {:?}",
            runs[0].0,
            runs[1].0,
            runs[2].0
        );
        Ok(())
    });
}
