//! Property tests over the MPI layer: collective schedules, the
//! non-blocking progress engine, the in-NI accelerator, and the
//! per-tenant QoS identity (single class ⇒ QoS is invisible).
//! Shared harness: `exanest::testing`.

use exanest::mpi::collectives::{bcast_schedule, recursive_doubling_schedule};
use exanest::mpi::{progress, pt2pt, Placement, World};
use exanest::network::{NetworkModel, RoutePolicy};
use exanest::prop_assert;
use exanest::sim::{SimDuration, SimTime};
use exanest::testing::{forall, with_workers};
use exanest::topology::{QfdbId, SystemConfig, Topology};

#[test]
fn prop_bcast_schedule_covers_all_once() {
    forall("binomial bcast covers each rank exactly once", 200, |rng| {
        let n = rng.range(2, 700) as usize;
        let mut got = vec![false; n];
        got[0] = true;
        for step in bcast_schedule(n) {
            for (s, d) in step {
                prop_assert!(got[s], "n={n}: {s} sends before covered");
                prop_assert!(!got[d], "n={n}: {d} covered twice");
                got[d] = true;
            }
        }
        prop_assert!(got.iter().all(|&x| x), "n={n}: not all covered");
        Ok(())
    });
}

#[test]
fn prop_recursive_doubling_is_allreduce() {
    // executing the schedule with real vectors yields the global sum on
    // every rank
    forall("recursive doubling computes the global sum", 100, |rng| {
        let n = 1usize << rng.range(1, 6);
        let mut vals: Vec<i64> = (0..n).map(|_| rng.below(1000) as i64).collect();
        let want: i64 = vals.iter().sum();
        for step in recursive_doubling_schedule(n) {
            let mut next = vals.clone();
            for (a, b) in step {
                let s = vals[a] + vals[b];
                next[a] = s;
                next[b] = s;
            }
            vals = next;
        }
        prop_assert!(vals.iter().all(|&v| v == want), "n={n}: {vals:?} != {want}");
        Ok(())
    });
}

#[test]
fn prop_eager_latency_monotone_in_distance() {
    let cfg = SystemConfig::prototype();
    forall("pt2pt latency grows with torus distance", 60, |rng| {
        let topo = Topology::new(cfg.clone());
        let qa = QfdbId(rng.below(32) as u32);
        let qb = QfdbId(rng.below(32) as u32);
        let da = topo.qfdb_distance(QfdbId(0), qa);
        let db = topo.qfdb_distance(QfdbId(0), qb);
        if da == db {
            return Ok(());
        }
        let mut w = World::new(cfg.clone(), 128, Placement::PerMpsoc);
        let ra = (qa.0 * 4) as usize;
        let rb = (qb.0 * 4) as usize;
        if ra == 0 || rb == 0 {
            return Ok(());
        }
        let la = pt2pt::send_recv(&mut w, 0, ra, 0).recv_done;
        w.reset();
        let lb = pt2pt::send_recv(&mut w, 0, rb, 0).recv_done;
        let (near, far) = if da < db { (la, lb) } else { (lb, la) };
        prop_assert!(near <= far, "distance {da} vs {db}: {near:?} vs {far:?}");
        Ok(())
    });
}

#[test]
fn prop_nonblocking_reproduces_blocking_to_the_nanosecond() {
    // Refactor seam: the event-driven send_recv (isend + irecv + wait on
    // the progress engine) must reproduce the closed-form blocking oracle
    // exactly — over random placements, endpoints, sizes and chains of
    // messages (so fabric occupancy carries over between operations).
    let cfg = SystemConfig::prototype();
    forall("isend+wait == blocking send_recv (ps exact)", 40, |rng| {
        let placement = if rng.below(2) == 0 { Placement::PerCore } else { Placement::PerMpsoc };
        let n = 16usize;
        let mut oracle = World::new(cfg.clone(), n, placement);
        let mut event = World::new(cfg.clone(), n, placement);
        for _ in 0..8 {
            let src = rng.below(n as u64) as usize;
            let dst = rng.below(n as u64) as usize;
            if src == dst {
                continue;
            }
            let bytes = [0usize, 8, 32, 33, 64, 4096, 100_000][rng.below(7) as usize];
            // oracle: closed-form message() with the old blocking clock
            // semantics (clocks *set* to the completion times)
            let ts = oracle.clocks[src];
            let tr = oracle.clocks[dst];
            let m = pt2pt::message(&mut oracle, src, dst, bytes, ts, tr);
            oracle.clocks[src] = m.send_done;
            oracle.clocks[dst] = m.recv_done;
            // event-driven path
            let r = pt2pt::send_recv(&mut event, src, dst, bytes);
            prop_assert!(
                r.send_done == m.send_done && r.recv_done == m.recv_done,
                "{src}->{dst} {bytes} B: event ({:?}, {:?}) vs oracle ({:?}, {:?})",
                r.send_done,
                r.recv_done,
                m.send_done,
                m.recv_done
            );
            prop_assert!(
                event.clocks[src] == oracle.clocks[src]
                    && event.clocks[dst] == oracle.clocks[dst],
                "clocks diverged after {src}->{dst}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_wait_all_order_is_irrelevant() {
    // completion times must not depend on the order requests are waited on
    let cfg = SystemConfig::prototype();
    forall("wait order independence", 30, |rng| {
        let n = 16usize;
        let mut wa = World::new(cfg.clone(), n, Placement::PerMpsoc);
        let mut wb = World::new(cfg.clone(), n, Placement::PerMpsoc);
        let bytes = [64usize, 4096, 65536][rng.below(3) as usize];
        // two disjoint pairs in flight together
        let post = |w: &mut World| {
            let s1 = progress::isend(w, 0, 1, bytes);
            let r1 = progress::irecv(w, 1, 0, bytes);
            let s2 = progress::isend(w, 2, 3, bytes);
            let r2 = progress::irecv(w, 3, 2, bytes);
            [s1, r1, s2, r2]
        };
        let ra = post(&mut wa);
        let rb = post(&mut wb);
        let da: Vec<SimTime> = ra.iter().map(|&q| progress::wait(&mut wa, q)).collect();
        let db: Vec<SimTime> = rb.iter().rev().map(|&q| progress::wait(&mut wb, q)).collect();
        for (i, &d) in da.iter().enumerate() {
            prop_assert!(
                db[3 - i] == d,
                "request {i}: forward-wait {d:?} != reverse-wait {:?}",
                db[3 - i]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_send_recv_never_goes_backwards() {
    let cfg = SystemConfig::prototype();
    forall("clocks are monotone under random traffic", 40, |rng| {
        let mut w = World::new(cfg.clone(), 64, Placement::PerCore);
        for _ in 0..50 {
            let a = rng.below(64) as usize;
            let b = rng.below(64) as usize;
            if a == b {
                continue;
            }
            let before = (w.clocks[a], w.clocks[b]);
            let bytes = match rng.below(3) {
                0 => 8,
                1 => 4096,
                _ => 128 * 1024,
            };
            let r = pt2pt::send_recv(&mut w, a, b, bytes as usize);
            prop_assert!(w.clocks[a] >= before.0, "sender clock regressed");
            prop_assert!(w.clocks[b] >= before.1, "receiver clock regressed");
            prop_assert!(r.recv_done >= r.send_done || bytes <= 32,
                "recv before send done for rendezvous");
        }
        Ok(())
    });
}

#[test]
fn prop_allreduce_phases_reduce_every_rank_count() {
    // executing the fold-in / recursive-doubling / fold-out phases with
    // real vectors yields the global sum on every rank, for ANY count
    use exanest::mpi::collectives::allreduce_phases;
    forall("generalized allreduce computes the global sum", 150, |rng| {
        let n = rng.range(1, 50) as usize;
        let mut vals: Vec<i64> = (0..n).map(|_| rng.below(1000) as i64 - 500).collect();
        let total: i64 = vals.iter().sum();
        let phases = allreduce_phases(n);
        for &(even, odd) in &phases.pre {
            let v = vals[even];
            vals[odd] += v;
        }
        for step in &phases.main {
            for &(a, b) in step {
                let s = vals[a] + vals[b];
                vals[a] = s;
                vals[b] = s;
            }
        }
        for &(odd, even) in &phases.post {
            vals[even] = vals[odd];
        }
        prop_assert!(
            vals.iter().all(|&v| v == total),
            "n={n}: ranks disagree with total {total}: {vals:?}"
        );
        Ok(())
    });
}

#[test]
fn prop_allreduce_timing_completes_for_any_rank_count() {
    // the timed schedule must run (no power-of-two assert) and cost at
    // least as much as the embedded power-of-two doubling phase alone
    use exanest::mpi::collectives;
    let cfg = SystemConfig::prototype();
    forall("allreduce timing at random rank counts", 15, |rng| {
        let n = rng.range(2, 40) as usize;
        let mut w = World::new(cfg.clone(), n, Placement::PerCore);
        let lat = collectives::allreduce(&mut w, 64);
        prop_assert!(lat.ns() > 0.0, "n={n}: zero allreduce latency");
        if !n.is_power_of_two() {
            let pof2 = n.next_power_of_two() / 2;
            let mut wp = World::new(cfg.clone(), pof2, Placement::PerCore);
            let base = collectives::allreduce(&mut wp, 64);
            prop_assert!(
                lat > base,
                "n={n}: folded allreduce {lat} not above pof2 {pof2} base {base}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_accel_and_software_allreduce_values_agree() {
    // the accelerator's hardware reduction tree and a sequential software
    // reduction must produce identical values (integer-valued f32 inputs
    // keep every sum exact, so tree reassociation cannot hide drift)
    use exanest::accel::{AccelAllreduce, AccelOp};
    forall("accel tree == software sequential reduction", 200, |rng| {
        let nranks = 1usize << rng.range(0, 5); // 1..=32
        let len = rng.range(1, 70) as usize;
        let op = [AccelOp::Sum, AccelOp::Min, AccelOp::Max][rng.below(3) as usize];
        let contributions: Vec<Vec<f32>> = (0..nranks)
            .map(|_| (0..len).map(|_| (rng.below(2000) as i64 - 1000) as f32).collect())
            .collect();
        let tree = AccelAllreduce::allreduce_f32_native(op, &contributions);
        // sequential software reference
        let mut seq = contributions[0].clone();
        for c in &contributions[1..] {
            for (a, b) in seq.iter_mut().zip(c) {
                *a = match op {
                    AccelOp::Sum => *a + *b,
                    AccelOp::Min => a.min(*b),
                    AccelOp::Max => a.max(*b),
                };
            }
        }
        prop_assert!(
            tree == seq,
            "op {op:?}, {nranks} ranks x {len}: tree {tree:?} != sequential {seq:?}"
        );
        Ok(())
    });
}

#[test]
fn prop_accel_beats_software_by_paper_margin_on_cell_model() {
    // Fig 19's headline: for small vectors at rendez-vous sizes the in-NI
    // accelerator cuts >= 80% off the software allreduce at 4-64 ranks —
    // asserted on the cell-level router mesh, where both paths pay real
    // per-cell forwarding
    use exanest::mpi::collectives::{allreduce_via, Backend};
    let cfg = SystemConfig::prototype();
    forall("accel >= 80% faster than software (cell model)", 8, |rng| {
        let n = [4usize, 16, 64][rng.below(3) as usize];
        let bytes = [64usize, 256][rng.below(2) as usize];
        let model = NetworkModel::cell(RoutePolicy::Deterministic);
        let mut w = World::with_model(cfg.clone(), n, Placement::PerMpsoc, model);
        let (sw, used_sw) = allreduce_via(&mut w, bytes, Backend::Software);
        prop_assert!(used_sw == Backend::Software, "software dispatch");
        w.reset();
        let (hw, used_hw) = allreduce_via(&mut w, bytes, Backend::Accel);
        prop_assert!(used_hw == Backend::Accel, "n={n} satisfies the accel constraints");
        prop_assert!(
            hw.ns() < 0.2 * sw.ns(),
            "n={n}, {bytes} B: accel {} us vs software {} us (< 80% improvement)",
            hw.us(),
            sw.us()
        );
        Ok(())
    });
}

#[test]
fn prop_single_class_qos_is_ps_identical_and_worker_invariant() {
    // QoS acceptance (DESIGN.md §15): with only one tenant class in
    // flight the deficit round-robin arbiter is exact FIFO and ECN
    // marking sees no cross-class occupancy, so a QoS-enabled world must
    // time ps-identically to a QoS-off one — on the cell model, for both
    // the arbitration-only and the throttled profile (the latter drops to
    // the single-threaded reference path, which must change nothing),
    // and invariantly across 1, 2 and 4 DES workers.
    use exanest::topology::QosConfig;
    let base = SystemConfig::two_blades();
    forall("single class: QoS on == off (ps), any workers", 4, |rng| {
        let n = [8usize, 16][rng.below(2) as usize];
        let bytes = [1024usize, 4096][rng.below(2) as usize];
        let model = NetworkModel::cell(RoutePolicy::Deterministic);
        let mut runs: Vec<(String, SimDuration, Vec<SimTime>)> = Vec::new();
        for workers in [1usize, 2, 4] {
            for (tag, qos) in [
                ("off", QosConfig::default()),
                ("arb", QosConfig::arbitration_only()),
                ("thr", QosConfig::throttled()),
            ] {
                let mut cfg = with_workers(&base, workers);
                cfg.qos = qos;
                let mut w =
                    World::with_model(cfg, n, Placement::PerMpsoc, model.clone());
                let lat = exanest::mpi::collectives::allreduce(&mut w, bytes);
                prop_assert!(
                    w.fabric.cells_marked() == 0,
                    "w={workers} {tag}: single-class run marked cells"
                );
                prop_assert!(
                    w.progress.window_halvings() == 0,
                    "w={workers} {tag}: single-class run halved a window"
                );
                runs.push((format!("w{workers}/{tag}"), lat, w.clocks.clone()));
            }
        }
        let (_, lat0, clocks0) = &runs[0];
        for (name, lat, clocks) in &runs[1..] {
            prop_assert!(
                lat == lat0 && clocks == clocks0,
                "{n} ranks x {bytes} B: {name} diverged from w1/off \
                 ({lat:?} vs {lat0:?})"
            );
        }
        Ok(())
    });
}
