//! Property tests over the critical-path blame engine (DESIGN.md §16):
//! the per-message decomposition must partition every traced window
//! ps-exact on both network models, an incast hotspot must charge its
//! wait to router queueing on the congested link, and the critical-path
//! walk must name the straggler behind an injected slow (lossy) link.
//! Shared harness: `exanest::testing`.

use exanest::mpi::collectives::{self, Backend};
use exanest::mpi::{progress, pt2pt, Placement, World};
use exanest::network::{FaultPlan, NetworkModel, RoutePolicy};
use exanest::prop_assert;
use exanest::telemetry::{BlameReport, CriticalPath};
use exanest::testing::forall;
use exanest::topology::SystemConfig;

/// Analyze a traced world and check the partition property on every
/// reassembled message: component sums equal the measured end-to-end
/// window with no residual, in integer picoseconds.
fn assert_ps_exact(w: &World, what: &str) -> Result<BlameReport, String> {
    let recs = w.trace_records();
    let rep = BlameReport::analyze(&recs);
    prop_assert!(!rep.messages.is_empty(), "{what}: trace reassembled no messages");
    for m in &rep.messages {
        prop_assert!(
            m.blame.total() == m.latency_ps(),
            "{what}: flow {} decomposition {} ps != window {} ps ({:?})",
            m.flow,
            m.blame.total(),
            m.latency_ps(),
            m.blame
        );
    }
    Ok(rep)
}

#[test]
fn prop_blame_partitions_single_message_ps_exact_on_both_models() {
    let cfg = SystemConfig::two_blades();
    forall("single message blame sums ps-exact (flow + cell)", 40, |rng| {
        let model = if rng.below(2) == 0 {
            NetworkModel::Flow
        } else {
            NetworkModel::cell(RoutePolicy::Deterministic)
        };
        let n = 8usize;
        let a = rng.below(n as u64) as usize;
        let mut b = rng.below(n as u64) as usize;
        if a == b {
            b = (b + 1) % n;
        }
        // eager (8/32) and rendez-vous handshake + RDMA (4 KB, 64 KB)
        let bytes = [8usize, 32, 4096, 64 * 1024][rng.below(4) as usize];
        let mut w = World::with_model(cfg.clone(), n, Placement::PerMpsoc, model);
        w.enable_tracing(1 << 16);
        pt2pt::send_recv(&mut w, a, b, bytes);
        let rep = assert_ps_exact(&w, &format!("{a}->{b} {bytes} B"))?;
        prop_assert!(
            rep.messages.iter().any(|m| m.bytes == bytes as u64),
            "{a}->{b}: no reassembled message carries the sent {bytes} B"
        );
        Ok(())
    });
}

#[test]
fn prop_blame_partitions_256_rank_allreduce_ps_exact_flow_model() {
    let cfg = SystemConfig::rack();
    forall("256-rank allreduce blame sums ps-exact (flow)", 4, |rng| {
        let bytes = [8usize, 32, 4096][rng.below(3) as usize];
        let mut w = World::with_model(cfg.clone(), 256, Placement::PerMpsoc, NetworkModel::Flow);
        w.enable_tracing(1 << 18);
        collectives::allreduce_via(&mut w, bytes, Backend::Software);
        let rep = assert_ps_exact(&w, &format!("256-rank {bytes} B allreduce"))?;
        // recursive doubling: every rank sends every step, so the trace
        // reassembles a full collective's worth of messages
        prop_assert!(
            rep.messages.len() >= 256,
            "only {} messages from a 256-rank collective",
            rep.messages.len()
        );
        Ok(())
    });
}

#[test]
fn blame_partitions_256_rank_allreduce_ps_exact_cell_model() {
    let cfg = SystemConfig::rack();
    let model = NetworkModel::cell(RoutePolicy::Deterministic);
    let mut w = World::with_model(cfg, 256, Placement::PerMpsoc, model);
    w.enable_tracing(1 << 18);
    collectives::allreduce_via(&mut w, 32, Backend::Software);
    let recs = w.trace_records();
    let rep = BlameReport::analyze(&recs);
    assert!(rep.messages.len() >= 256, "only {} messages", rep.messages.len());
    for m in &rep.messages {
        assert_eq!(
            m.blame.total(),
            m.latency_ps(),
            "flow {} must decompose ps-exact on the cell model: {:?}",
            m.flow,
            m.blame
        );
    }
    // the cell model's per-hop spans must actually feed the split: the
    // collective as a whole crossed wires, so serialization shows up
    assert!(rep.total.serialization > 0, "no Hop time attributed: {:?}", rep.total);
}

/// Seven senders, one per remote QFDB, all bursting 64 KiB into rank 0
/// at once on the cell mesh: the incast hotspot.  Messages serialize on
/// the shared path into rank 0's QFDB, so the k-th served message spends
/// about (k-1) transfer times waiting for wire grants — which the
/// decomposition must charge to `queueing` (HopQueue), and the blamed
/// dominant link of the slow messages must agree on where the hotspot
/// is.
#[test]
fn incast_hotspot_attributes_dominant_blame_to_queueing() {
    let cfg = SystemConfig::two_blades();
    let n = cfg.num_mpsocs(); // PerMpsoc: rank r lives on MPSoC r
    let model = NetworkModel::cell(RoutePolicy::Deterministic);
    let mut w = World::with_model(cfg, n, Placement::PerMpsoc, model);
    w.enable_tracing(1 << 20);
    let bytes = 64 * 1024usize;
    let senders: Vec<usize> = (1..8).map(|q| q * 4).collect(); // one rank per other QFDB
    let mut reqs = Vec::new();
    for &s in &senders {
        reqs.push(progress::irecv(&mut w, 0, s, bytes));
        reqs.push(progress::isend(&mut w, s, 0, bytes));
    }
    progress::wait_all(&mut w, &reqs);
    let recs = w.trace_records();
    let rep = BlameReport::analyze(&recs);
    assert_eq!(rep.messages.len(), senders.len());
    for m in &rep.messages {
        assert_eq!(m.blame.total(), m.latency_ps(), "flow {} not ps-exact", m.flow);
    }
    // queueing is the single largest aggregate component
    let t = &rep.total;
    for (name, ps) in t.parts() {
        if name != "queueing" {
            assert!(
                t.queueing > ps,
                "queueing ({} ps) must dominate {name} ({ps} ps) in an incast: {t:?}",
                t.queueing
            );
        }
    }
    // the slowest message mostly waited, and the slow messages agree on
    // which link the hotspot is
    let mut by_lat: Vec<&exanest::telemetry::MessageBlame> = rep.messages.iter().collect();
    by_lat.sort_by_key(|m| std::cmp::Reverse(m.latency_ps()));
    let worst = by_lat[0];
    assert!(
        worst.blame.queueing as f64 >= 0.4 * worst.latency_ps() as f64,
        "slowest incast message should be mostly queueing: {:?}",
        worst.blame
    );
    let hot = worst.dominant_link.expect("congested message has per-hop spans").0;
    for m in &by_lat[1..3] {
        assert_eq!(
            m.dominant_link.map(|(l, _)| l),
            Some(hot),
            "slow messages disagree on the congested link"
        );
    }
}

/// A seeded bit-error process makes the wire between two ranks lossy —
/// the "injected slow link".  The victim's 64 KiB transfer is all but
/// guaranteed a corrupted cell, so the reliable transport retransmits
/// and the message completes late.  The critical path must run through
/// the victim message and its straggler edge must carry more time than
/// the whole fast control message took.
#[test]
fn critical_path_names_the_straggler_behind_an_injected_slow_link() {
    let cfg = SystemConfig::two_blades();
    let model = NetworkModel::cell_with_faults(
        RoutePolicy::Deterministic,
        FaultPlan::none().with_ber(1e-4, 7),
    );
    let mut w = World::with_model(cfg, 8, Placement::PerMpsoc, model);
    w.enable_tracing(1 << 20);
    // fast control message, untouched by the loss process with high
    // probability (64 bits at BER 1e-4)
    pt2pt::send_recv(&mut w, 2, 3, 8);
    // victim: 64 KiB = ~0.5 M bits, corruption is effectively certain
    pt2pt::send_recv(&mut w, 0, 1, 64 * 1024);
    assert!(
        w.progress.retransmissions() > 0,
        "the injected lossy link never fired — victim too small or BER too low?"
    );
    let recs = w.trace_records();
    let rep = BlameReport::analyze(&recs);
    let victim = rep
        .messages
        .iter()
        .find(|m| m.bytes == 64 * 1024)
        .expect("victim message reassembled");
    assert_eq!(victim.blame.total(), victim.latency_ps());
    assert!(
        victim.blame.backoff > 0,
        "retransmission dead time must be blamed on backoff: {:?}",
        victim.blame
    );
    let path = CriticalPath::extract(&recs).expect("traced run has a critical path");
    assert_eq!(
        path.edges.iter().map(|e| e.contribution_ps).sum::<u64>(),
        path.total_ps(),
        "edge contributions must telescope exactly"
    );
    assert!(
        path.edges.iter().any(|e| e.flow == victim.flow),
        "critical path must run through the victim message"
    );
    let control = rep.messages.iter().find(|m| m.bytes == 8).expect("control message");
    let s = path.straggler().expect("non-empty path has a straggler");
    assert!(
        s.contribution_ps > control.latency_ps(),
        "straggler edge ({} ps, {:?}) should dwarf the whole control message ({} ps)",
        s.contribution_ps,
        s.kind,
        control.latency_ps()
    );
}
