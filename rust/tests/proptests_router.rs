//! Property tests over routing and the cell-level router mesh:
//! dimension-order tables, the dense route cache, zero-load parity with
//! the closed-form oracle, adaptive-policy degeneration, and cell-train
//! batching.  Shared harness: `exanest::testing`.

use exanest::mpi::{pt2pt, Placement, World};
use exanest::network::{Fabric, FaultPlan, NetworkModel, RoutePolicy, RouterMesh};
use exanest::prop_assert;
use exanest::sim::{SimDuration, SimTime};
use exanest::testing::forall;
use exanest::topology::{route, Dir, MpsocId, QfdbId, SystemConfig, Topology};

#[test]
fn prop_route_reaches_and_matches_distance() {
    let topo = Topology::new(SystemConfig::prototype());
    forall("DOR route reaches dst with torus distance", 300, |rng| {
        let n = topo.cfg.num_qfdbs() as u64;
        let a = QfdbId(rng.below(n) as u32);
        let b = QfdbId(rng.below(n) as u32);
        let dirs = topo.qfdb_route(a, b);
        let mut cur = a;
        for d in &dirs {
            cur = topo.qfdb_neighbor(cur, *d);
        }
        prop_assert!(cur == b, "route {a:?}->{b:?} ended at {cur:?}");
        prop_assert!(
            dirs.len() == topo.qfdb_distance(a, b),
            "route len {} != distance {}",
            dirs.len(),
            topo.qfdb_distance(a, b)
        );
        Ok(())
    });
}

#[test]
fn prop_route_is_dimension_ordered() {
    // deadlock freedom rests on X-then-Y-then-Z ordering
    let topo = Topology::new(SystemConfig::prototype());
    forall("routes are dimension ordered", 300, |rng| {
        let n = topo.cfg.num_qfdbs() as u64;
        let a = QfdbId(rng.below(n) as u32);
        let b = QfdbId(rng.below(n) as u32);
        let dirs = topo.qfdb_route(a, b);
        let phase = |d: &exanest::topology::Dir| match d {
            exanest::topology::Dir::XPlus | exanest::topology::Dir::XMinus => 0,
            exanest::topology::Dir::YPlus | exanest::topology::Dir::YMinus => 1,
            _ => 2,
        };
        let phases: Vec<i32> = dirs.iter().map(phase).collect();
        let mut sorted = phases.clone();
        sorted.sort();
        prop_assert!(phases == sorted, "not dimension ordered: {phases:?}");
        Ok(())
    });
}

#[test]
fn prop_path_hops_and_routers_consistent() {
    let topo = Topology::new(SystemConfig::prototype());
    forall("path router count = torus hops + 1 (when any)", 300, |rng| {
        let n = topo.cfg.num_mpsocs() as u64;
        let a = exanest::topology::MpsocId(rng.below(n) as u32);
        let b = exanest::topology::MpsocId(rng.below(n) as u32);
        let p = route(&topo, a, b);
        let torus_hops = p.hops().iter().filter(|h| h.link.is_torus()).count();
        if torus_hops > 0 {
            prop_assert!(
                p.routers == torus_hops + 1,
                "{a:?}->{b:?}: {} routers for {torus_hops} torus hops",
                p.routers
            );
        } else {
            prop_assert!(p.routers == 0, "intra-QFDB path has routers");
        }
        Ok(())
    });
}

#[test]
fn prop_route_cached_equals_route() {
    // Refactor seam: the dense route cache must be exact for every
    // endpoint pair, including repeated (cache-hit) queries.
    let cfg = SystemConfig::prototype();
    forall("Fabric::route_cached == route", 150, |rng| {
        let mut fab = Fabric::new(cfg.clone());
        let n = cfg.num_mpsocs() as u64;
        for _ in 0..4 {
            let a = MpsocId(rng.below(n) as u32);
            let b = MpsocId(rng.below(n) as u32);
            let fresh = fab.route(a, b);
            for query in 0..2 {
                let cached = fab.route_cached(a, b);
                prop_assert!(
                    cached.src == fresh.src
                        && cached.dst == fresh.dst
                        && cached.hops() == fresh.hops()
                        && cached.routers == fresh.routers
                        && cached.switches == fresh.switches,
                    "{a:?}->{b:?} query {query}: cached {cached:?} != fresh {fresh:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cell_level_zero_load_matches_oracle() {
    // The router-mesh seam: at zero load, cell-level deterministic
    // routing must reproduce the closed-form `pt2pt::message` oracle —
    // exactly (< 1%) for eager messages on any path and for rendez-vous
    // on single-link paths; multi-link rendez-vous may only be *faster*
    // (cells genuinely cut through intermediate routers, where the flow
    // model store-and-forwards whole blocks per hop).
    let cfg = SystemConfig::prototype();
    let topo = Topology::new(cfg.clone());
    forall("cell-level zero load == oracle", 25, |rng| {
        let n = cfg.num_mpsocs();
        let a = rng.below(n as u64) as usize;
        let b = rng.below(n as u64) as usize;
        if a == b {
            return Ok(());
        }
        let p = route(&topo, MpsocId(a as u32), MpsocId(b as u32));
        let single_link = p.hops().len() <= 1;
        let mut sizes: Vec<usize> = vec![0, 8, 32];
        if single_link {
            sizes.extend([64, 4096, 64 * 1024]);
        }
        for bytes in sizes {
            let mut flow = World::new(cfg.clone(), n, Placement::PerMpsoc);
            let mut cell = World::with_model(
                cfg.clone(),
                n,
                Placement::PerMpsoc,
                NetworkModel::cell(RoutePolicy::Deterministic),
            );
            let f = pt2pt::message(&mut flow, a, b, bytes, SimTime::ZERO, SimTime::ZERO);
            let c = pt2pt::message(&mut cell, a, b, bytes, SimTime::ZERO, SimTime::ZERO);
            let rel = (c.recv_done.ns() - f.recv_done.ns()).abs() / f.recv_done.ns();
            prop_assert!(
                rel < 0.01,
                "{a}->{b} {bytes} B: cell {:?} vs oracle {:?} ({rel:.4} off)",
                c.recv_done,
                f.recv_done
            );
        }
        // multi-link rendez-vous: cut-through must never be slower
        if !single_link {
            let mut flow = World::new(cfg.clone(), n, Placement::PerMpsoc);
            let mut cell = World::with_model(
                cfg.clone(),
                n,
                Placement::PerMpsoc,
                NetworkModel::cell(RoutePolicy::Deterministic),
            );
            let f = pt2pt::message(&mut flow, a, b, 64 * 1024, SimTime::ZERO, SimTime::ZERO);
            let c = pt2pt::message(&mut cell, a, b, 64 * 1024, SimTime::ZERO, SimTime::ZERO);
            prop_assert!(
                c.recv_done <= f.recv_done + SimDuration::from_ns(1.0),
                "{a}->{b}: cut-through {:?} slower than store-and-forward {:?}",
                c.recv_done,
                f.recv_done
            );
        }
        Ok(())
    });
}

#[test]
fn prop_adaptive_degenerates_to_dimension_order_when_idle() {
    // On an idle healthy mesh the adaptive policy's congestion signals
    // are all ties, so it must route and time exactly like the static
    // dimension-order tables.
    let cfg = SystemConfig::prototype();
    let topo = Topology::new(cfg.clone());
    forall("idle adaptive == dimension order", 60, |rng| {
        let nq = cfg.num_qfdbs() as u64;
        let qa = QfdbId(rng.below(nq) as u32);
        let qb = QfdbId(rng.below(nq) as u32);
        let det = RouterMesh::new(topo.clone(), RoutePolicy::Deterministic, FaultPlan::none());
        let ada = RouterMesh::new(topo.clone(), RoutePolicy::Adaptive, FaultPlan::none());
        prop_assert!(
            ada.probe_route(qa, qb, SimTime::ZERO) == det.probe_route(qa, qb, SimTime::ZERO),
            "{qa:?}->{qb:?}: adaptive route diverges on an idle mesh"
        );
        prop_assert!(
            det.probe_route(qa, qb, SimTime::ZERO) == topo.qfdb_route(qa, qb),
            "{qa:?}->{qb:?}: deterministic mesh route != static DOR table"
        );
        if qa != qb {
            let a = topo.network_mpsoc(qa);
            let b = topo.network_mpsoc(qb);
            let mut det = det;
            let mut ada = ada;
            let bytes = [256usize, 4096, 16 * 1024][rng.below(3) as usize];
            let d = det.block(a, b, SimTime::ZERO, bytes, false);
            let m = ada.block(a, b, SimTime::ZERO, bytes, false);
            prop_assert!(m == d, "{qa:?}->{qb:?} {bytes} B: adaptive {m:?} != DOR {d:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_route_cached_valid_after_reset() {
    // Satellite regression: `Fabric::reset` keeps the route cache, which
    // must therefore stay exact after arbitrary traffic + reset cycles.
    let cfg = SystemConfig::prototype();
    forall("route cache exact across reset", 40, |rng| {
        let mut fab = Fabric::new(cfg.clone());
        let n = cfg.num_mpsocs() as u64;
        let mut pairs = Vec::new();
        for _ in 0..4 {
            let a = MpsocId(rng.below(n) as u32);
            let b = MpsocId(rng.below(n) as u32);
            let p = fab.route_cached(a, b);
            if a != b {
                fab.small_cell(&p, SimTime::ZERO, 64);
                fab.rdma_block(&p, SimTime::ZERO, 4096, true);
            }
            pairs.push((a, b));
        }
        fab.reset();
        for (a, b) in pairs {
            let cached = fab.route_cached(a, b);
            let fresh = fab.route(a, b);
            prop_assert!(
                cached.hops() == fresh.hops()
                    && cached.routers == fresh.routers
                    && cached.switches == fresh.switches,
                "{a:?}->{b:?}: cache corrupted across reset"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_train_batching_matches_event_path() {
    // The batching parity contract: cell-train batching must be
    // ps-identical to per-cell event simulation under random traffic —
    // idle meshes, hotspot chains (blocks issued back-to-back into still-
    // busy wires), both policies, and fault plans (already-down links
    // batch onto the detour route; future fault times force both meshes
    // onto the event path).
    let cfg = SystemConfig::prototype();
    let topo = Topology::new(cfg.clone());
    forall("batched trains == per-cell events (ps exact)", 30, |rng| {
        let policy = if rng.below(2) == 0 {
            RoutePolicy::Deterministic
        } else {
            RoutePolicy::Adaptive
        };
        let nq = cfg.num_qfdbs() as u64;
        let faults = match rng.below(3) {
            0 => FaultPlan::none(),
            1 => FaultPlan::none().fail_torus(
                QfdbId(rng.below(nq) as u32),
                Dir::XPlus,
                SimTime::ZERO,
            ),
            _ => FaultPlan::none().fail_torus(
                QfdbId(rng.below(nq) as u32),
                Dir::YMinus,
                SimTime::from_us(30.0),
            ),
        };
        let mut fast = RouterMesh::new(topo.clone(), policy, faults.clone());
        let mut slow = RouterMesh::new(topo.clone(), policy, faults);
        slow.set_batching(false);
        let n = cfg.num_mpsocs() as u64;
        let mut at = SimTime::ZERO;
        for k in 0..8 {
            let a = MpsocId(rng.below(n) as u32);
            let b = MpsocId(rng.below(n) as u32);
            if a == b {
                continue;
            }
            if rng.below(4) == 0 {
                let payload = [0usize, 8, 32, 256][rng.below(4) as usize];
                let f = fast.small_cell(a, b, at, payload);
                let s = slow.small_cell(a, b, at, payload);
                prop_assert!(f == s, "call {k}: small_cell {a:?}->{b:?} {f:?} vs {s:?}");
            } else {
                let bytes = [1usize, 300, 4096, 16 * 1024][rng.below(4) as usize];
                let pipelined = rng.below(2) == 0;
                let f = fast.block(a, b, at, bytes, pipelined);
                let s = slow.block(a, b, at, bytes, pipelined);
                prop_assert!(
                    f == s,
                    "call {k}: block {a:?}->{b:?} {bytes} B at {at} — batched {f:?} vs events {s:?}"
                );
                if rng.below(2) == 0 {
                    at = f.0; // chain into the still-busy injection window
                }
            }
            if rng.below(3) == 0 {
                at = at + SimDuration::from_us(rng.below(40) as f64);
            }
        }
        Ok(())
    });
}
