//! Bench for Table 2 / Fig 14: osu_latency simulation across path classes.
use exanest::apps::osu::{osu_latency, OsuPath};
use exanest::bench::{black_box, Suite};
use exanest::topology::SystemConfig;

fn main() {
    let mut s = Suite::new("latency");
    let cfg = SystemConfig::prototype();
    for p in OsuPath::ALL {
        s.bench(&format!("osu_latency/{}/0B", p.label()), || {
            black_box(osu_latency(&cfg, p, 0, 10));
        });
    }
    s.bench("osu_latency/Intra-QFDB-sh/4MB", || {
        black_box(osu_latency(&cfg, OsuPath::IntraQfdbSh, 4 << 20, 2));
    });
    s.write_json().expect("write BENCH_latency.json");
}
