//! Bench for Fig 13: the IP-over-ExaNet tunnel model.
use exanest::bench::{bench, black_box};
use exanest::ip::{iperf, IpMode, Scenario, TunnelConfig};

fn main() {
    let tc = TunnelConfig::default();
    for s in Scenario::ALL {
        bench(&format!("ip_overlay/{}", s.label()), || {
            black_box(iperf(&tc, s, IpMode::Overlay, 5));
        });
    }
    bench("ip_baseline/UDP 1470B", || {
        black_box(iperf(&tc, Scenario::UdpLarge, IpMode::Baseline, 5));
    });
}
