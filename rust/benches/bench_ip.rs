//! Bench for Fig 13: the IP-over-ExaNet tunnel model.
use exanest::bench::{black_box, Suite};
use exanest::ip::{iperf, IpMode, Scenario, TunnelConfig};

fn main() {
    let mut s = Suite::new("ip");
    let tc = TunnelConfig::default();
    for sc in Scenario::ALL {
        s.bench(&format!("ip_overlay/{}", sc.label()), || {
            black_box(iperf(&tc, sc, IpMode::Overlay, 5));
        });
    }
    s.bench("ip_baseline/UDP 1470B", || {
        black_box(iperf(&tc, Scenario::UdpLarge, IpMode::Baseline, 5));
    });
    s.write_json().expect("write BENCH_ip.json");
}
