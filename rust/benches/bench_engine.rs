//! Bench for the simulation substrate itself (§Perf baseline): timing-
//! wheel event-queue throughput (near-horizon, rollover, far-future
//! overflow, posts into the past), fabric primitive costs, and the MPI
//! progress engine.  Stamps engine events/sec and peak queue depth into
//! `BENCH_engine.json`.
use std::time::Instant;

use exanest::bench::{black_box, Suite};
use exanest::mpi::{collectives, progress, Backend, Placement, World};
use exanest::network::{Fabric, NetworkModel, RoutePolicy};
use exanest::sim::{Engine, SimTime};
use exanest::topology::SystemConfig;

fn main() {
    let mut s = Suite::new("engine");
    s.stamp(&SystemConfig::prototype());
    // near-horizon traffic: timestamps within one wheel span (~67 us)
    s.bench("engine/schedule+drain/10k", || {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10_000u32 {
            e.schedule(SimTime(i as u64 * 7919 % 100_000), i);
        }
        let mut acc = 0u64;
        e.run(&mut acc, |a, _, _, i| {
            *a += i as u64;
            true
        });
        black_box(acc);
    });
    // rollover + overflow: timestamps spread over ~3000 wheel horizons,
    // exercising bucket laps and far-heap migration
    s.bench("engine/schedule+drain/far-future/10k", || {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10_000u32 {
            e.schedule(SimTime(i as u64 * 7919 * 2_718_281 % 200_000_000_000), i);
        }
        let mut acc = 0u64;
        e.run(&mut acc, |a, _, _, i| {
            *a += i as u64;
            true
        });
        black_box(acc);
    });
    // rank-local posts trailing the clock (the MPI progress pattern)
    s.bench("engine/post-past+drain/10k", || {
        let mut e: Engine<u32> = Engine::new();
        e.schedule(SimTime(1_000_000), 0);
        e.next();
        for i in 1..10_000u32 {
            e.post(SimTime(i as u64 * 101 % 2_000_000), i);
        }
        while e.next().is_some() {}
        black_box(e.processed());
    });

    let mut fab = Fabric::new(SystemConfig::prototype());
    let a = fab.topo.mpsoc(0, 0, 0);
    let b = fab.topo.mpsoc(6, 1, 2);
    let p = fab.route(a, b);
    s.bench("fabric/small_cell/6hops", || {
        black_box(fab.small_cell(&p, SimTime::ZERO, 32));
    });
    s.bench("fabric/rdma_block/6hops", || {
        black_box(fab.rdma_block(&p, SimTime::ZERO, 16 * 1024, true));
    });
    s.bench("fabric/route/6hops", || {
        black_box(fab.route(a, b));
    });
    // the nonblocking runtime's post + event-chain + match overhead
    // (world hoisted out so the number tracks the progress engine, not
    // topology construction; recycle keeps the request table flat)
    let cfg = SystemConfig::prototype();
    let mut w = World::new(cfg, 8, Placement::PerCore);
    s.bench("progress/isend+irecv+wait/eager", || {
        let sr = progress::isend(&mut w, 0, 4, 8);
        let rr = progress::irecv(&mut w, 4, 0, 8);
        black_box(progress::wait_all(&mut w, &[sr, rr]));
        w.progress.recycle();
    });

    // raw wheel throughput metric: events/sec through a full
    // schedule-and-drain cycle of near-horizon traffic
    let t0 = Instant::now();
    let mut e: Engine<u32> = Engine::new();
    let rounds = 50u64;
    for _ in 0..rounds {
        for i in 0..10_000u32 {
            e.schedule(e.now() + exanest::sim::SimDuration(i as u64 * 7919 % 100_000), i);
        }
        while e.next().is_some() {}
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    s.metric("engine/events_per_sec", e.processed() as f64 / wall, "1/s");
    s.metric("engine/peak_queue_depth", e.peak_pending() as f64, "events");
    // Queue pressure of the MPI event chains over a FIXED workload (1000
    // eager pingpongs on a fresh world) — the adaptive bench harness
    // above runs a host-speed-dependent iteration count, which would
    // make these counters machine noise instead of a trajectory metric.
    w.reset();
    for _ in 0..1000 {
        let sr = progress::isend(&mut w, 0, 4, 8);
        let rr = progress::irecv(&mut w, 4, 0, 8);
        progress::wait_all(&mut w, &[sr, rr]);
        w.progress.recycle();
    }
    s.metric("progress/events_processed", w.progress.events_processed() as f64, "events");
    s.metric("progress/peak_queue_depth", w.progress.peak_queue_depth() as f64, "events");

    s.write_json().expect("write BENCH_engine.json");

    // Parallel-DES scaling (DESIGN.md §12): the same full-rack
    // cell-level software allreduce at 1/2/4/8 workers.  Simulated
    // latency must be bit-identical at every worker count (asserted
    // here); what scales is wall-clock events/sec.  `null_msgs_per_op`
    // is the conservative-synchronization overhead: time-bound
    // broadcasts per deferred fabric operation.
    let mut p = Suite::new("parallel");
    p.stamp(&SystemConfig::rack());
    let mut base_eps = 0.0f64;
    let mut base_lat = None;
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = SystemConfig::rack();
        cfg.sim_workers = workers;
        let model = NetworkModel::cell(RoutePolicy::Deterministic);
        let t0 = Instant::now();
        let mut w = World::with_model(cfg, 256, Placement::PerCore, model);
        let (lat, _) = collectives::allreduce_via(&mut w, 64 * 1024, Backend::Software);
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let events = w.progress.events_processed() as f64;
        let eps = events / wall;
        match base_lat {
            None => {
                base_lat = Some(lat);
                base_eps = eps;
                p.metric("latency_us", lat.us(), "us");
                p.metric("events", events, "count");
            }
            Some(reference) => assert_eq!(
                lat, reference,
                "{workers} workers diverged from the single-threaded result"
            ),
        }
        p.metric(&format!("w{workers}/events_per_sec"), eps, "1/s");
        p.metric(&format!("w{workers}/wall_s"), wall, "s");
        p.metric(&format!("w{workers}/speedup"), eps / base_eps.max(1e-9), "x");
        if let Some(ps) = w.par_stats() {
            p.metric(&format!("w{workers}/windows"), ps.windows as f64, "count");
            p.metric(&format!("w{workers}/components"), ps.components as f64, "count");
            p.metric(&format!("w{workers}/shipped_ops"), ps.shipped as f64, "count");
            p.metric(
                &format!("w{workers}/null_msgs_per_op"),
                ps.bounds_sent as f64 / (ps.ops as f64).max(1.0),
                "x",
            );
        }
    }
    p.write_json().expect("write BENCH_parallel.json");
}
