//! Bench for the simulation substrate itself (§Perf baseline): event
//! queue throughput, fabric primitive costs, and the MPI progress engine.
use exanest::bench::{black_box, Suite};
use exanest::mpi::{progress, Placement, World};
use exanest::network::Fabric;
use exanest::sim::{Engine, SimTime};
use exanest::topology::SystemConfig;

fn main() {
    let mut s = Suite::new("engine");
    s.stamp(&SystemConfig::prototype());
    s.bench("engine/schedule+drain/10k", || {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10_000u32 {
            e.schedule(SimTime(i as u64 * 7919 % 100_000), i);
        }
        let mut acc = 0u64;
        e.run(&mut acc, |a, _, _, i| {
            *a += i as u64;
            true
        });
        black_box(acc);
    });
    let mut fab = Fabric::new(SystemConfig::prototype());
    let a = fab.topo.mpsoc(0, 0, 0);
    let b = fab.topo.mpsoc(6, 1, 2);
    let p = fab.route(a, b);
    s.bench("fabric/small_cell/6hops", || {
        black_box(fab.small_cell(&p, SimTime::ZERO, 32));
    });
    s.bench("fabric/rdma_block/6hops", || {
        black_box(fab.rdma_block(&p, SimTime::ZERO, 16 * 1024, true));
    });
    s.bench("fabric/route/6hops", || {
        black_box(fab.route(a, b));
    });
    // the nonblocking runtime's post + event-chain + match overhead
    // (world hoisted out so the number tracks the progress engine, not
    // topology construction; recycle keeps the request table flat)
    let cfg = SystemConfig::prototype();
    let mut w = World::new(cfg, 8, Placement::PerCore);
    s.bench("progress/isend+irecv+wait/eager", || {
        let sr = progress::isend(&mut w, 0, 4, 8);
        let rr = progress::irecv(&mut w, 4, 0, 8);
        black_box(progress::wait_all(&mut w, &[sr, rr]));
        w.progress.recycle();
    });
    s.write_json().expect("write BENCH_engine.json");
}
