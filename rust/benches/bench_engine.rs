//! Bench for the simulation substrate itself (§Perf baseline): event
//! queue throughput and fabric primitive costs.
use exanest::bench::{bench, black_box};
use exanest::network::Fabric;
use exanest::sim::{Engine, SimDuration, SimTime};
use exanest::topology::SystemConfig;

fn main() {
    bench("engine/schedule+drain/10k", || {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10_000u32 {
            e.schedule(SimTime(i as u64 * 7919 % 100_000), i);
        }
        let mut acc = 0u64;
        e.run(&mut acc, |a, _, _, i| {
            *a += i as u64;
            true
        });
        black_box(acc);
    });
    let mut fab = Fabric::new(SystemConfig::prototype());
    let a = fab.topo.mpsoc(0, 0, 0);
    let b = fab.topo.mpsoc(6, 1, 2);
    let p = fab.route(a, b);
    bench("fabric/small_cell/6hops", || {
        black_box(fab.small_cell(&p, SimTime::ZERO, 32));
    });
    bench("fabric/rdma_block/6hops", || {
        black_box(fab.rdma_block(&p, SimTime::ZERO, 16 * 1024, true));
    });
    bench("fabric/route/6hops", || {
        black_box(fab.route(a, b));
    });
    let _ = SimDuration::ZERO;
}
