//! Bench for Figs 16-18: bcast/allreduce simulations + the Eq.1 model.
use exanest::apps::osu::{osu_allreduce, osu_bcast};
use exanest::bench::{bench, black_box};
use exanest::model::expected_bcast;
use exanest::mpi::Placement;
use exanest::topology::SystemConfig;

fn main() {
    let cfg = SystemConfig::prototype();
    for n in [16usize, 64, 512] {
        bench(&format!("osu_bcast/{n}ranks/1B"), || {
            black_box(osu_bcast(&cfg, n, 1, 1, 42));
        });
    }
    bench("osu_bcast/512ranks/1MB", || {
        black_box(osu_bcast(&cfg, 512, 1 << 20, 1, 42));
    });
    for n in [16usize, 512] {
        bench(&format!("osu_allreduce/{n}ranks/4B"), || {
            black_box(osu_allreduce(&cfg, n, 4, 1, Placement::PerCore));
        });
    }
    bench("bcast_model/eq1/512ranks", || {
        black_box(expected_bcast(&cfg, 512, 1));
    });
}
