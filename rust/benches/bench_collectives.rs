//! Bench for Figs 16-18: bcast/allreduce simulations + the Eq.1 model,
//! plus the new scatter/alltoall schedules.
use exanest::apps::osu::{osu_allreduce, osu_bcast};
use exanest::bench::{black_box, Suite};
use exanest::model::expected_bcast;
use exanest::mpi::{collectives, Placement, World};
use exanest::topology::SystemConfig;

fn main() {
    let mut s = Suite::new("collectives");
    let cfg = SystemConfig::prototype();
    for n in [16usize, 64, 512] {
        s.bench(&format!("osu_bcast/{n}ranks/1B"), || {
            black_box(osu_bcast(&cfg, n, 1, 1, 42));
        });
    }
    s.bench("osu_bcast/512ranks/1MB", || {
        black_box(osu_bcast(&cfg, 512, 1 << 20, 1, 42));
    });
    for n in [16usize, 512] {
        s.bench(&format!("osu_allreduce/{n}ranks/4B"), || {
            black_box(osu_allreduce(&cfg, n, 4, 1, Placement::PerCore));
        });
    }
    s.bench("alltoall/64ranks/1KB", || {
        let mut w = World::new(cfg.clone(), 64, Placement::PerCore);
        black_box(collectives::alltoall(&mut w, 1024));
    });
    // the generalized (non-power-of-two) schedule: fold-in + doubling + fold-out
    s.bench("allreduce/12ranks/64B/folded", || {
        let mut w = World::new(cfg.clone(), 12, Placement::PerCore);
        black_box(collectives::allreduce(&mut w, 64));
    });
    // the backend dispatcher routing to the event-retimed accelerator
    s.bench("allreduce_via/accel/64ranks/256B", || {
        let mut w = World::new(cfg.clone(), 64, Placement::PerMpsoc);
        black_box(collectives::allreduce_via(&mut w, 256, collectives::Backend::Accel));
    });
    s.bench("scatter/512ranks/1KB", || {
        let mut w = World::new(cfg.clone(), 512, Placement::PerCore);
        black_box(collectives::scatter(&mut w, 1024));
    });
    s.bench("bcast_model/eq1/512ranks", || {
        black_box(expected_bcast(&cfg, 512, 1));
    });
    s.write_json().expect("write BENCH_collectives.json");
}
