//! Bench for §7: the matmul accelerator — cycle model + PJRT numerics.
use exanest::accel::MatmulAccel;
use exanest::bench::{black_box, Suite};
use exanest::runtime::Executor;

fn main() {
    let mut s = Suite::new("matmul");
    let m = MatmulAccel::default();
    s.bench("matmul_accel/model/n=2048", || {
        black_box(m.gflops(2048));
    });
    // PJRT execution benches (the real hot path the coordinator drives)
    if let Ok(mut exec) = Executor::open_default() {
        let a = vec![1.0f32; 128 * 128];
        let b = vec![0.5f32; 128 * 128];
        s.bench("matmul_accel/pjrt/tile128", || {
            black_box(exec.run_f32("matmul_tile128", &[&a, &b]).unwrap());
        });
        let a2 = vec![1.0f32; 256 * 256];
        let b2 = vec![0.5f32; 256 * 256];
        s.bench("matmul_accel/pjrt/256", || {
            black_box(exec.run_f32("matmul_256", &[&a2, &b2]).unwrap());
        });
    } else {
        eprintln!("artifacts not built; skipping PJRT benches");
    }
    s.write_json().expect("write BENCH_matmul.json");
}
