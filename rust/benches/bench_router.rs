//! Bench for the cell-level router mesh: per-cell forwarding cost vs the
//! flow model, policy overhead, and the hotspot scenario end to end.
use exanest::bench::{black_box, Suite};
use exanest::network::{Fabric, FaultPlan, NetworkModel, RoutePolicy, RouterMesh};
use exanest::sim::SimTime;
use exanest::topology::{QfdbId, SystemConfig, Topology};

fn main() {
    let cfg = SystemConfig::prototype();
    let mut s = Suite::new("router");
    s.stamp(&cfg);

    let topo = Topology::new(cfg.clone());
    let a = topo.mpsoc(0, 0, 1);
    let b = topo.mpsoc(6, 1, 2);
    let mut mesh = RouterMesh::new(topo.clone(), RoutePolicy::Deterministic, FaultPlan::none());
    s.bench("mesh/small_cell/6hops", || {
        black_box(mesh.small_cell(a, b, SimTime::ZERO, 32));
    });
    s.bench("mesh/block16k/6hops", || {
        black_box(mesh.block(a, b, SimTime::ZERO, 16 * 1024, true));
    });
    let mut adaptive = RouterMesh::new(topo.clone(), RoutePolicy::Adaptive, FaultPlan::none());
    s.bench("mesh/block16k/6hops/adaptive", || {
        black_box(adaptive.block(a, b, SimTime::ZERO, 16 * 1024, true));
    });
    s.bench("mesh/probe_route/5hops", || {
        black_box(mesh.probe_route(QfdbId(0), QfdbId(26), SimTime::ZERO));
    });

    // same primitives through the Fabric seam, for flow-vs-cell overhead
    let mut flow = Fabric::new(cfg.clone());
    let mut cell = Fabric::with_model(cfg.clone(), NetworkModel::cell(RoutePolicy::Deterministic));
    let p = flow.route(a, b);
    s.bench("fabric-flow/rdma_block/6hops", || {
        black_box(flow.rdma_block(&p, SimTime::ZERO, 16 * 1024, true));
    });
    s.bench("fabric-cell/rdma_block/6hops", || {
        black_box(cell.rdma_block(&p, SimTime::ZERO, 16 * 1024, true));
    });

    // the hotspot scenario, end to end on the MPI runtime
    s.bench("osu_mbw_hotspot/adaptive/64k", || {
        black_box(exanest::apps::osu::osu_mbw_hotspot(&cfg, RoutePolicy::Adaptive, 64 * 1024, 2));
    });
    s.write_json().expect("write BENCH_router.json");
}
