//! Bench for the cell-level router mesh: per-cell forwarding cost vs the
//! flow model, policy overhead, the train fast path vs the per-cell
//! event reference, and the hotspot scenario end to end — on the
//! prototype and on the full 256-MPSoC rack.
//!
//! Besides wall times, the suite stamps simulator-throughput metrics
//! (events/sec of the per-cell engine, peak event-queue depth, and the
//! train-batching speedup) into `BENCH_router.json` so the perf
//! trajectory is tracked PR-over-PR.
use std::time::Instant;

use exanest::bench::{black_box, Suite};
use exanest::network::{Fabric, FaultPlan, NetworkModel, RoutePolicy, RouterMesh};
use exanest::sim::SimTime;
use exanest::topology::{QfdbId, SystemConfig, Topology};

fn main() {
    let cfg = SystemConfig::prototype();
    let mut s = Suite::new("router");
    s.stamp(&cfg);

    let topo = Topology::new(cfg.clone());
    let a = topo.mpsoc(0, 0, 1);
    let b = topo.mpsoc(6, 1, 2);
    let mut mesh = RouterMesh::new(topo.clone(), RoutePolicy::Deterministic, FaultPlan::none());
    s.bench("mesh/small_cell/6hops", || {
        black_box(mesh.small_cell(a, b, SimTime::ZERO, 32));
    });
    s.bench("mesh/block16k/6hops", || {
        black_box(mesh.block(a, b, SimTime::ZERO, 16 * 1024, true));
    });
    // the train fast path vs the per-cell event reference: meshes hoisted
    // out so the samples time only block() (construction would otherwise
    // dilute the speedup ratio); timestamps chain through src_free so
    // every iteration runs the steady-state busy-wire case
    let mut fastm = RouterMesh::new(topo.clone(), RoutePolicy::Deterministic, FaultPlan::none());
    let mut fast_at = SimTime::ZERO;
    let m_batched = s.bench("mesh/block16k/6hops/batched", || {
        let (free, _) = fastm.block(a, b, fast_at, 16 * 1024, true);
        fast_at = black_box(free);
    });
    let batched_ns = m_batched.median();
    let mut slowm = RouterMesh::new(topo.clone(), RoutePolicy::Deterministic, FaultPlan::none());
    slowm.set_batching(false);
    let mut slow_at = SimTime::ZERO;
    let m_events = s.bench("mesh/block16k/6hops/event-path", || {
        let (free, _) = slowm.block(a, b, slow_at, 16 * 1024, true);
        slow_at = black_box(free);
    });
    let event_ns = m_events.median();
    s.metric("train_batching_speedup/block16k_6hops", event_ns / batched_ns.max(1e-12), "x");

    let mut adaptive = RouterMesh::new(topo.clone(), RoutePolicy::Adaptive, FaultPlan::none());
    s.bench("mesh/block16k/6hops/adaptive", || {
        black_box(adaptive.block(a, b, SimTime::ZERO, 16 * 1024, true));
    });
    s.bench("mesh/probe_route/5hops", || {
        black_box(mesh.probe_route(QfdbId(0), QfdbId(26), SimTime::ZERO));
    });

    // same primitives through the Fabric seam, for flow-vs-cell overhead
    let mut flow = Fabric::new(cfg.clone());
    let mut cell = Fabric::with_model(cfg.clone(), NetworkModel::cell(RoutePolicy::Deterministic));
    let p = flow.route(a, b);
    s.bench("fabric-flow/rdma_block/6hops", || {
        black_box(flow.rdma_block(&p, SimTime::ZERO, 16 * 1024, true));
    });
    s.bench("fabric-cell/rdma_block/6hops", || {
        black_box(cell.rdma_block(&p, SimTime::ZERO, 16 * 1024, true));
    });

    // the hotspot scenario, end to end on the MPI runtime (same bench
    // name as PR 2 so the trajectory shows the batching speedup)
    s.bench("osu_mbw_hotspot/adaptive/64k", || {
        black_box(exanest::apps::osu::osu_mbw_hotspot(&cfg, RoutePolicy::Adaptive, 64 * 1024, 2));
    });

    // full 256-MPSoC rack: the tentpole's target scale
    let rack = SystemConfig::rack();
    let rtopo = Topology::new(rack.clone());
    let ra = rtopo.mpsoc(0, 0, 1);
    let rb = rtopo.mpsoc(10, 2, 2); // 2+2+2 ring hops + fan in/out: the rack's longest path
    let mut rmesh = RouterMesh::new(rtopo.clone(), RoutePolicy::Deterministic, FaultPlan::none());
    s.bench("mesh/block16k/rack-8hops", || {
        black_box(rmesh.block(ra, rb, SimTime::ZERO, 16 * 1024, true));
    });
    s.bench("osu_mbw_hotspot/adaptive/rack/64k", || {
        black_box(exanest::apps::osu::osu_mbw_hotspot(&rack, RoutePolicy::Adaptive, 64 * 1024, 2));
    });

    // raw event-engine throughput + queue pressure on the rack shape
    // (batching off so the per-cell engine is actually exercised)
    let mut emesh = RouterMesh::new(rtopo.clone(), RoutePolicy::Deterministic, FaultPlan::none());
    emesh.set_batching(false);
    let t0 = Instant::now();
    let mut at = SimTime::ZERO;
    for _ in 0..64 {
        let (free, _) = emesh.block(ra, rb, at, 16 * 1024, true);
        at = free;
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    s.metric("event_path/events_per_sec/rack", emesh.events_processed() as f64 / wall, "1/s");
    s.metric("event_path/peak_queue_depth/rack", emesh.peak_queue_depth() as f64, "events");
    s.metric("event_path/events_per_block16k", emesh.events_processed() as f64 / 64.0, "events");

    s.write_json().expect("write BENCH_router.json");
}
