//! Bench for Figs 20-22 / Table 3: application scaling simulations.
use exanest::apps::scaling::{run_point, AppParams, Mode};
use exanest::bench::{bench, black_box};
use exanest::topology::SystemConfig;

fn main() {
    let cfg = SystemConfig::prototype();
    for app in [AppParams::lammps(), AppParams::hpcg(), AppParams::minife()] {
        for (mode, tag) in [(Mode::Weak, "weak"), (Mode::Strong, "strong")] {
            bench(&format!("scaling/{}/{tag}/512ranks", app.name), || {
                black_box(run_point(&cfg, &app, 512, mode));
            });
        }
    }
}
