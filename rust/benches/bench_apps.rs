//! Bench for Figs 20-22 / Table 3: the event-driven proxy applications.
use exanest::apps::scaling::{run_point, AppParams, HaloSchedule, Mode, ProxyConfig};
use exanest::bench::{black_box, Suite};
use exanest::mpi::Backend;
use exanest::topology::SystemConfig;

fn main() {
    let mut s = Suite::new("apps");
    let cfg = SystemConfig::prototype();
    s.stamp(&cfg);
    let proxy = ProxyConfig::default();
    // captured from the benched runs themselves — no extra simulation
    let mut hpcg_weak = None;
    for app in [AppParams::lammps(), AppParams::hpcg(), AppParams::minife()] {
        for (mode, tag) in [(Mode::Weak, "weak"), (Mode::Strong, "strong")] {
            s.bench(&format!("scaling/{}/{tag}/512ranks", app.name), || {
                let m = run_point(&cfg, &app, 512, mode, &proxy);
                if app.name == "hpcg" && mode == Mode::Weak {
                    hpcg_weak = Some(m);
                } else {
                    black_box(m);
                }
            });
        }
    }
    // the maximally overlapped halo schedule and the accel dispatch path
    let hpcg = AppParams::hpcg();
    let all_faces = ProxyConfig { halo: HaloSchedule::AllFaces, ..ProxyConfig::default() };
    s.bench("scaling/hpcg/weak/512ranks/all-faces", || {
        black_box(run_point(&cfg, &hpcg, 512, Mode::Weak, &all_faces));
    });
    let accel = ProxyConfig { backend: Backend::Accel, ..ProxyConfig::default() };
    s.bench("scaling/hpcg/weak/64ranks/accel", || {
        black_box(run_point(&cfg, &hpcg, 64, Mode::Weak, &accel));
    });
    // stamp the headline simulation outputs next to the host-time numbers
    if let Some(m) = hpcg_weak {
        s.metric("hpcg/weak/comm_fraction@512ranks", m.comm_fraction, "frac");
        s.metric("hpcg/weak/halo_overlap@512ranks", m.overlap_fraction, "frac");
    }
    s.write_json().expect("write BENCH_apps.json");
}
