//! Bench for Figs 20-22 / Table 3: application scaling simulations.
use exanest::apps::scaling::{run_point, AppParams, Mode};
use exanest::bench::{black_box, Suite};
use exanest::topology::SystemConfig;

fn main() {
    let mut s = Suite::new("apps");
    let cfg = SystemConfig::prototype();
    for app in [AppParams::lammps(), AppParams::hpcg(), AppParams::minife()] {
        for (mode, tag) in [(Mode::Weak, "weak"), (Mode::Strong, "strong")] {
            s.bench(&format!("scaling/{}/{tag}/512ranks", app.name), || {
                black_box(run_point(&cfg, &app, 512, mode));
            });
        }
    }
    s.write_json().expect("write BENCH_apps.json");
}
