//! Bench for Fig 19: the NI Allreduce accelerator vs the software path.
use exanest::accel::AccelAllreduce;
use exanest::apps::osu::osu_allreduce;
use exanest::bench::{black_box, Suite};
use exanest::mpi::{Placement, World};
use exanest::topology::SystemConfig;

fn main() {
    let mut s = Suite::new("allreduce_accel");
    let cfg = SystemConfig::prototype();
    for n in [16usize, 128] {
        s.bench(&format!("allreduce_accel/{n}ranks/256B"), || {
            let mut w = World::new(cfg.clone(), n, Placement::PerMpsoc);
            black_box(AccelAllreduce::latency(&mut w, 256));
        });
        s.bench(&format!("allreduce_sw/{n}ranks/256B"), || {
            black_box(osu_allreduce(&cfg, n, 256, 1, Placement::PerMpsoc));
        });
    }
    s.write_json().expect("write BENCH_allreduce_accel.json");
}
