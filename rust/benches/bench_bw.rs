//! Bench for Fig 15: osu_bw / osu_bibw simulation, plus the multi-pair
//! osu_mbw_mr congestion scenario on the nonblocking runtime.
use exanest::apps::osu::{osu_bibw, osu_bw, osu_mbw_mr, shared_link_pairs, OsuPath};
use exanest::bench::{black_box, Suite};
use exanest::topology::{SystemConfig, Topology};

fn main() {
    let mut s = Suite::new("bw");
    let cfg = SystemConfig::prototype();
    for p in [OsuPath::IntraQfdbSh, OsuPath::IntraMezzSh, OsuPath::InterMezz312] {
        s.bench(&format!("osu_bw/{}/4MB", p.label()), || {
            black_box(osu_bw(&cfg, p, 4 << 20, 64));
        });
    }
    s.bench("osu_bibw/Intra-QFDB-sh/4MB", || {
        black_box(osu_bibw(&cfg, OsuPath::IntraQfdbSh, 4 << 20, 64));
    });
    let topo = Topology::new(cfg.clone());
    let pairs = shared_link_pairs(&topo, 4);
    s.bench("osu_mbw_mr/4pairs-shared-link/1MBx4", || {
        black_box(osu_mbw_mr(&cfg, &pairs, 1 << 20, 4));
    });
    s.write_json().expect("write BENCH_bw.json");
}
