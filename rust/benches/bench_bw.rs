//! Bench for Fig 15: osu_bw / osu_bibw simulation.
use exanest::apps::osu::{osu_bibw, osu_bw, OsuPath};
use exanest::bench::{bench, black_box};
use exanest::topology::SystemConfig;

fn main() {
    let cfg = SystemConfig::prototype();
    for p in [OsuPath::IntraQfdbSh, OsuPath::IntraMezzSh, OsuPath::InterMezz312] {
        bench(&format!("osu_bw/{}/4MB", p.label()), || {
            black_box(osu_bw(&cfg, p, 4 << 20, 64));
        });
    }
    bench("osu_bibw/Intra-QFDB-sh/4MB", || {
        black_box(osu_bibw(&cfg, OsuPath::IntraQfdbSh, 4 << 20, 64));
    });
}
