"""AOT compile path: lower every Layer-2 function to HLO *text* artifacts.

HLO text (not ``serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Usage:  python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per registry entry plus ``manifest.txt``
describing the I/O signature of each artifact, which the rust
``runtime::Executor`` parses at load time:

    <name> in=<dtype>:<dims>x... [,...] out=<dtype>:<dims>x... [,...]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)  # f64 allreduce variants


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(s) -> str:
    dt = {"float32": "f32", "float64": "f64", "int32": "i32"}[str(s.dtype)]
    dims = "x".join(str(d) for d in s.shape) or "scalar"
    return f"{dt}:{dims}"


def registry() -> list:
    """(artifact name, fn, example args) for every AOT export."""
    ents = []

    # --- Section 7 matmul accelerator -----------------------------------
    ents.append(("matmul_tile128", model.matmul_tile_once,
                 [spec((128, 128)), spec((128, 128))]))
    ents.append(("matmul_256", model.matmul_paper,
                 [spec((256, 256)), spec((256, 256))]))
    ents.append(("matmul_512", model.matmul_paper,
                 [spec((512, 512)), spec((512, 512))]))

    # --- Section 4.7 allreduce accelerator ALU ---------------------------
    for op in ("sum", "min", "max"):
        ents.append((f"allreduce_{op}_f32_64", model.allreduce_combine(op),
                     [spec((64,)), spec((64,))]))
    ents.append(("allreduce_sum_f64_32", model.allreduce_combine("sum"),
                 [spec((32,), jnp.float64), spec((32,), jnp.float64)]))
    ents.append(("allreduce_sum_i32_64", model.allreduce_combine("sum"),
                 [spec((64,), jnp.int32), spec((64,), jnp.int32)]))
    # a 4 KB vector for the software-allreduce data path
    ents.append(("allreduce_sum_f32_1024", model.allreduce_combine("sum"),
                 [spec((1024,)), spec((1024,))]))

    # --- HPCG/miniFE CG per-rank steps, at the e2e example's grid sizes --
    for n in (8, 24, 48):
        p = n + 2
        ents.append((f"cg_pre_{n}", model.cg_pre, [spec((p, p, p))]))
        ents.append((f"cg_post_{n}", model.cg_post,
                     [spec((n, n, n))] * 4 + [spec((1,))]))
        ents.append((f"cg_update_p_{n}", model.cg_update_p,
                     [spec((n, n, n))] * 2 + [spec((1,))]))

    return ents


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names to (re)build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest_lines = []
    for name, fn, argspecs in registry():
        sig_in = ",".join(_sig(s) for s in argspecs)
        lowered = jax.jit(fn).lower(*argspecs)
        flat, _ = jax.tree.flatten(lowered.out_info)
        sig_out = ",".join(_sig(s) for s in flat)
        manifest_lines.append(f"{name} in={sig_in} out={sig_out}")
        if only is not None and name not in only:
            continue
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {args.out_dir}/manifest.txt ({len(manifest_lines)} entries)")


if __name__ == "__main__":
    main()
