"""Pure-jnp correctness oracles for every Layer-1 Pallas kernel.

These are deliberately written with a *different* algorithmic shape than the
kernels (no tiling, no blocked grids, jnp.roll instead of slice loops) so a
bug in the Pallas plumbing cannot cancel out in the comparison.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .stencil27 import DIAG, OFF


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Dense matmul oracle."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def combine(a: jax.Array, b: jax.Array, op: str = "sum") -> jax.Array:
    """Elementwise pairwise reduce oracle."""
    if op == "sum":
        return a + b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    raise ValueError(op)


def spmv(x_padded: jax.Array) -> jax.Array:
    """27-point SpMV oracle built from jnp.roll over the padded block."""
    acc = jnp.zeros_like(x_padded)
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                w = DIAG if (dz, dy, dx) == (0, 0, 0) else OFF
                acc = acc + w * jnp.roll(x_padded, (-dz, -dy, -dx), (0, 1, 2))
    return acc[1:-1, 1:-1, 1:-1]


def dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.sum(a.reshape(-1) * b.reshape(-1)).reshape(1)


def axpy(alpha: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    return alpha.reshape(1)[0] * x + y


def spmv_dense(x_padded) -> jax.Array:
    """Second, even more literal oracle: materialise the operator as a dense
    matrix over the interior points and do a dense matvec.  Only usable for
    tiny grids; used by one pytest to anchor the roll-based oracle itself."""
    import numpy as np

    nz, ny, nx = (d - 2 for d in x_padded.shape)
    n = nz * ny * nx
    xp = np.asarray(x_padded)
    a = np.zeros((n, n), dtype=np.float64)
    rhs_halo = np.zeros(n, dtype=np.float64)

    def idx(z, y, x):
        return (z * ny + y) * nx + x

    for z in range(nz):
        for y in range(ny):
            for x in range(nx):
                row = idx(z, y, x)
                for dz in (-1, 0, 1):
                    for dy in (-1, 0, 1):
                        for dx in (-1, 0, 1):
                            w = DIAG if (dz, dy, dx) == (0, 0, 0) else OFF
                            zz, yy, xx = z + dz, y + dy, x + dx
                            if 0 <= zz < nz and 0 <= yy < ny and 0 <= xx < nx:
                                a[row, idx(zz, yy, xx)] += w
                            else:
                                # halo contribution becomes an additive term
                                rhs_halo[row] += w * xp[zz + 1, yy + 1, xx + 1]
    interior = xp[1:-1, 1:-1, 1:-1].reshape(-1).astype(np.float64)
    return (a @ interior + rhs_halo).reshape(nz, ny, nx).astype(np.float32)
