"""Pallas kernels for the CG compute hot-spot of HPCG / miniFE.

HPCG's operator is the 27-point stencil on a structured 3-D grid: the
matrix row for an interior point has 26.0 on the diagonal and -1.0 for each
of its 26 neighbours (HPCG reference problem).  SpMV against that operator
is the dominant kernel of both HPCG and miniFE's CG solve, so it is the
Layer-1 hot-spot for the application-level experiments (Figs 21-22) and for
the end-to-end example.

The grid sizes used by the simulated ranks are small (local subgrids of a
few tens cubed), so the whole padded block fits in one VMEM block; larger
grids would block over the z axis with a one-plane halo per block.

Also provides the CG vector primitives (dot, axpy) as trivial Pallas
kernels, so a full CG iteration lowers into pure Pallas compute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: HPCG operator coefficients: diag 26, off-diagonal -1 over 26 neighbours.
DIAG = 26.0
OFF = -1.0


def _stencil_kernel(x_ref, o_ref):
    """27-point SpMV: x_ref is the halo-padded (n+2)^3 block, o is n^3."""
    x = x_ref[...]
    acc = DIAG * x[1:-1, 1:-1, 1:-1]
    # 26 neighbour contributions; the (0,0,0) offset is the diagonal above.
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                if dz == 0 and dy == 0 and dx == 0:
                    continue
                acc = acc + OFF * x[
                    1 + dz: x.shape[0] - 1 + dz,
                    1 + dy: x.shape[1] - 1 + dy,
                    1 + dx: x.shape[2] - 1 + dx,
                ]
    o_ref[...] = acc


@jax.jit
def spmv(x_padded: jax.Array) -> jax.Array:
    """SpMV with the 27-point operator. Input is halo-padded by one plane.

    ``x_padded`` has shape (nz+2, ny+2, nx+2); the result has shape
    (nz, ny, nx).  Boundary (Dirichlet) conditions are expressed by the
    caller filling the halo with zeros; distributed ranks fill it with
    neighbour data received over the simulated ExaNet fabric.
    """
    nz, ny, nx = (d - 2 for d in x_padded.shape)
    return pl.pallas_call(
        _stencil_kernel,
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), jnp.float32),
        interpret=True,
    )(x_padded)


def _dot_kernel(a_ref, b_ref, o_ref):
    o_ref[0] = jnp.sum(a_ref[...] * b_ref[...])


@jax.jit
def dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Local dot product -> shape-(1,) result (allreduced by the L3 layer)."""
    assert a.shape == b.shape
    return pl.pallas_call(
        _dot_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(a.reshape(-1), b.reshape(-1))


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


@jax.jit
def axpy(alpha: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """o = alpha * x + y with a scalar carried as a shape-(1,) array."""
    assert x.shape == y.shape
    return pl.pallas_call(
        _axpy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(alpha.reshape(1), x, y)


@functools.partial(jax.jit, static_argnames=())
def pad_halo(x: jax.Array) -> jax.Array:
    """Zero-pad a (nz,ny,nx) block by one halo plane on every face."""
    return jnp.pad(x, 1)
