"""Pallas port of the Allreduce accelerator ALU (paper Section 4.7).

The HLS accelerator reduces vectors in 256-byte blocks (the maximum ExaNet
cell payload) with sum/min/max over int, float and double datatypes.  Here
the vector ALU is a Pallas elementwise kernel over 256-byte blocks; the
rust `accel::allreduce` model invokes the AOT-compiled pairwise combine at
every level of the reduction tree, so the simulated collective produces
real numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: operations supported by the accelerator (paper §4.7)
OPS = ("sum", "min", "max")
#: datatypes supported by the accelerator (paper §4.7: int, float, double)
DTYPES = {"f32": jnp.float32, "f64": jnp.float64, "i32": jnp.int32}

#: the accelerator's native block: 256 bytes (one ExaNet cell payload)
BLOCK_BYTES = 256


def _combine_kernel(op: str, a_ref, b_ref, o_ref):
    a, b = a_ref[...], b_ref[...]
    if op == "sum":
        o_ref[...] = a + b
    elif op == "min":
        o_ref[...] = jnp.minimum(a, b)
    elif op == "max":
        o_ref[...] = jnp.maximum(a, b)
    else:  # pragma: no cover - guarded by OPS
        raise ValueError(f"unsupported op {op!r}")


@functools.partial(jax.jit, static_argnames=("op",))
def combine(a: jax.Array, b: jax.Array, *, op: str = "sum") -> jax.Array:
    """Pairwise elementwise reduction of two equal-shape 1-D vectors.

    Blocked in units of 256 bytes like the hardware; lengths must be a
    multiple of one block (the rust caller pads, like the accelerator's
    software driver does).
    """
    assert op in OPS, f"op must be one of {OPS}"
    assert a.shape == b.shape and a.ndim == 1
    assert a.dtype == b.dtype
    elems_per_block = BLOCK_BYTES // a.dtype.itemsize
    n = a.shape[0]
    assert n % elems_per_block == 0, (
        f"length {n} not a multiple of the {elems_per_block}-element block"
    )
    grid = (n // elems_per_block,)
    kern = functools.partial(_combine_kernel, op)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((elems_per_block,), lambda i: (i,)),
            pl.BlockSpec((elems_per_block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((elems_per_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a, b)
