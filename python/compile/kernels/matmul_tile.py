"""Pallas port of the Section-7 HLS matrix-multiplication accelerator.

The paper's accelerator holds a 128x128 FP32 tile of each operand in BRAM,
fully unrolls the k-loop (128 MACs/cycle) and 4-way unrolls the j-loop,
i.e. 512 MACs/cycle at 300 MHz, with three AXI HP ports streaming tiles
from DDR.  The TPU-style rethink (DESIGN.md §Hardware-Adaptation):

- BRAM tile            -> Pallas VMEM block (``BlockSpec``)
- unrolled MAC array   -> one MXU ``jnp.dot`` per grid step
- AXI load/unload + double buffering -> the automatic Pallas HBM<->VMEM
  pipeline implied by the grid/BlockSpec schedule.

The grid is (M/bm, N/bn, K/bk) with k innermost so each (i, j) output block
stays resident in VMEM while partial products accumulate — exactly the HLS
"keep C tile in BRAM across the k loop" plan.

VMEM footprint at the paper's tile (128,128,128): 3 x 128x128x4 B = 192 KiB,
comfortably inside a TPU core's ~16 MiB VMEM; MXU utilisation estimate is
derived in DESIGN.md §Perf (the 128x128 f32 block maps to 1 MXU pass per
8x8x8 systolic step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper's tile geometry: 128x128, k fully unrolled over 128.
PAPER_TILE = (128, 128, 128)


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One grid step: accumulate x_block @ y_block into the output block."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128) -> jax.Array:
    """Tiled matmul via the Pallas kernel.

    Shapes must be multiples of the block sizes (the paper's accelerator has
    the same restriction: arrays are padded to tile multiples by the host).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not a multiple of tile ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU-PJRT execution; Mosaic lowering is TPU-only
    )(x, y)


def vmem_bytes(bm: int = 128, bn: int = 128, bk: int = 128,
               dtype_bytes: int = 4) -> int:
    """VMEM footprint of one grid step (A, B and C blocks), in bytes."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)
