"""Layer-1 Pallas kernels: the FPGA accelerator datapaths of the ExaNeSt paper.

Each kernel mirrors one piece of FPGA logic from the paper:

- ``matmul_tile``  — the Section-7 HLS matrix-multiplication accelerator:
  a 128x128 FP32 tile held in BRAM (here: a Pallas VMEM block) with the
  k-loop fully unrolled (here: one MXU ``jnp.dot`` per grid step).
- ``reduce_vec``   — the Allreduce accelerator ALU (Section 4.7):
  elementwise sum/min/max over 256-byte vector blocks.
- ``stencil27``    — the HPCG/miniFE compute hot-spot: a 27-point stencil
  SpMV on a structured grid, plus the dot/axpy vector ops of the CG solver.

All kernels are lowered with ``interpret=True``: real-TPU Pallas emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute.  Correctness is
checked against the pure-jnp oracles in ``ref.py`` by the pytest suite.
"""

from . import matmul_tile, reduce_vec, stencil27, ref  # noqa: F401
