"""Layer-2 JAX compute graphs, composed from the Layer-1 Pallas kernels.

These are the functions that get AOT-lowered to HLO text by ``aot.py`` and
executed from the rust coordinator via PJRT.  Python never runs on the
request path: every function here is traced exactly once at build time.

The CG functions implement the per-rank compute of a distributed conjugate
gradient solve (the computational core of both miniFE and HPCG):
the L3 rust layer owns the halo exchanges and the dot-product allreduces,
so the per-rank steps are split at exactly those communication points:

    cg_pre:      Ap = A p   (27-pt stencil on the halo-padded p),
                 local <p, Ap>                  -> then L3 allreduces pAp
    cg_post:     x += alpha p; r -= alpha Ap; local <r, r>
                                                -> then L3 allreduces rr
    cg_update_p: p = r + beta p                 -> then L3 halo-exchanges p
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import matmul_tile, reduce_vec, stencil27


# --------------------------------------------------------------------------
# Section 7: the matrix-multiplication accelerator workload
# --------------------------------------------------------------------------

def matmul_paper(x: jax.Array, y: jax.Array) -> tuple[jax.Array]:
    """The paper's accelerator composed over a full matrix (tiled 128^3)."""
    return (matmul_tile.matmul(x, y),)


def matmul_tile_once(x: jax.Array, y: jax.Array) -> tuple[jax.Array]:
    """Exactly one accelerator tile (the HLS kernel itself, one block)."""
    return (matmul_tile.matmul(x, y, bm=x.shape[0], bn=y.shape[1],
                               bk=x.shape[1]),)


# --------------------------------------------------------------------------
# Section 4.7: the Allreduce accelerator ALU
# --------------------------------------------------------------------------

def allreduce_combine(op: str):
    """Pairwise combine for one tree level of the Allreduce accelerator."""

    def fn(a: jax.Array, b: jax.Array) -> tuple[jax.Array]:
        return (reduce_vec.combine(a, b, op=op),)

    fn.__name__ = f"allreduce_combine_{op}"
    return fn


# --------------------------------------------------------------------------
# HPCG / miniFE: per-rank CG compute between communication points
# --------------------------------------------------------------------------

def cg_pre(p_padded: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Ap = A p (halo already filled by L3); local partial <p, Ap>."""
    ap = stencil27.spmv(p_padded)
    p_interior = p_padded[1:-1, 1:-1, 1:-1]
    return ap, stencil27.dot(p_interior, ap)


def cg_post(x: jax.Array, r: jax.Array, p: jax.Array, ap: jax.Array,
            alpha: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x' = x + alpha p ; r' = r - alpha Ap ; local partial <r', r'>."""
    x2 = stencil27.axpy(alpha, p, x)
    r2 = stencil27.axpy(-alpha, ap, r)
    return x2, r2, stencil27.dot(r2, r2)


def cg_update_p(r: jax.Array, p: jax.Array,
                beta: jax.Array) -> tuple[jax.Array]:
    """p' = r + beta p (then L3 refreshes the halo of p')."""
    return (stencil27.axpy(beta, p, r),)


def cg_solve_single(b: jax.Array, iters: int) -> tuple[jax.Array, jax.Array]:
    """Single-rank CG reference loop (used by pytest, not AOT-exported).

    Solves A x = b on one zero-halo grid, returning (x, residual-norm
    history).  Mirrors what the distributed rust driver does with the AOT
    artifacts, so the e2e example can be validated against it.
    """
    x = jnp.zeros_like(b)
    r = b
    p = r
    rr = stencil27.dot(r, r)[0]
    hist = [jnp.sqrt(rr)]
    for _ in range(iters):
        ap, pap = cg_pre(stencil27.pad_halo(p))
        alpha = rr / pap[0]
        x, r, rr_new = cg_post(x, r, p, ap, jnp.asarray([alpha]))
        rr_new = rr_new[0]
        beta = rr_new / rr
        (p,) = cg_update_p(r, p, jnp.asarray([beta]))
        rr = rr_new
        hist.append(jnp.sqrt(rr))
    return x, jnp.stack(hist)
