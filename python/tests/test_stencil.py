"""Pallas 27-point stencil SpMV + CG vector ops vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stencil27

jax.config.update("jax_enable_x64", True)


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


class TestSpmv:
    def test_constant_interior_zero_halo(self):
        """For all-ones interior + zero halo, an interior-of-interior point
        sees 26*1 - 26*1 = 0."""
        n = 5
        x = jnp.pad(jnp.ones((n, n, n), jnp.float32), 1)
        y = np.asarray(stencil27.spmv(x))
        np.testing.assert_allclose(y[2, 2, 2], 0.0, atol=1e-6)
        # corner point has only 7 interior neighbours: 26 - 7 = 19
        np.testing.assert_allclose(y[0, 0, 0], 19.0, atol=1e-5)

    def test_matches_roll_oracle(self):
        x = _rand((8, 8, 8), 0)
        xp = stencil27.pad_halo(x)
        np.testing.assert_allclose(
            np.asarray(stencil27.spmv(xp)), np.asarray(ref.spmv(xp)),
            rtol=1e-5, atol=1e-5)

    def test_nonzero_halo(self):
        """Distributed ranks fill the halo with neighbour data."""
        xp = _rand((6, 6, 6), 1)  # whole padded block random, halo nonzero
        np.testing.assert_allclose(
            np.asarray(stencil27.spmv(xp)), np.asarray(ref.spmv(xp)),
            rtol=1e-5, atol=1e-5)

    def test_dense_matrix_anchor(self):
        """Anchor both implementations to a literal dense-matrix matvec."""
        xp = _rand((5, 5, 5), 2)
        np.testing.assert_allclose(
            np.asarray(stencil27.spmv(xp)), ref.spmv_dense(xp),
            rtol=1e-4, atol=1e-4)

    def test_operator_is_spd_on_interior(self):
        """The HPCG operator (zero Dirichlet halo) must be SPD — CG's
        convergence precondition."""
        n = 3
        import numpy as onp
        dim = n ** 3
        a = onp.zeros((dim, dim), dtype=onp.float64)
        for i in range(dim):
            e = onp.zeros(dim, onp.float32)
            e[i] = 1.0
            xp = jnp.pad(jnp.asarray(e.reshape(n, n, n)), 1)
            a[:, i] = onp.asarray(stencil27.spmv(xp)).reshape(-1)
        np.testing.assert_allclose(a, a.T, atol=1e-5)
        eig = onp.linalg.eigvalsh(a)
        assert eig.min() > 0, f"min eigenvalue {eig.min()} not positive"

    def test_rectangular_block(self):
        xp = _rand((4, 6, 8), 3)
        np.testing.assert_allclose(
            np.asarray(stencil27.spmv(xp)), np.asarray(ref.spmv(xp)),
            rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(nz=st.integers(2, 6), ny=st.integers(2, 6), nx=st.integers(2, 6),
           seed=st.integers(0, 2**31 - 1))
    def test_property_matches_oracle(self, nz, ny, nx, seed):
        xp = _rand((nz + 2, ny + 2, nx + 2), seed)
        np.testing.assert_allclose(
            np.asarray(stencil27.spmv(xp)), np.asarray(ref.spmv(xp)),
            rtol=1e-4, atol=1e-4)


class TestVectorOps:
    def test_dot(self):
        a, b = _rand((6, 6, 6), 4), _rand((6, 6, 6), 5)
        np.testing.assert_allclose(
            np.asarray(stencil27.dot(a, b))[0],
            float(np.sum(np.asarray(a) * np.asarray(b))), rtol=1e-4)

    def test_axpy(self):
        x, y = _rand((4, 4, 4), 6), _rand((4, 4, 4), 7)
        alpha = jnp.asarray([0.37], jnp.float32)
        np.testing.assert_allclose(
            np.asarray(stencil27.axpy(alpha, x, y)),
            0.37 * np.asarray(x) + np.asarray(y), rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 8), seed=st.integers(0, 2**31 - 1),
           alpha=st.floats(-10, 10, width=32))
    def test_property_axpy_dot(self, n, seed, alpha):
        x, y = _rand((n, n, n), seed), _rand((n, n, n), seed + 1)
        al = jnp.asarray([alpha], jnp.float32)
        np.testing.assert_allclose(
            np.asarray(stencil27.axpy(al, x, y)),
            np.asarray(ref.axpy(al, x, y)), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(stencil27.dot(x, y)), np.asarray(ref.dot(x, y)),
            rtol=1e-3, atol=1e-3)
