"""Pallas matmul tile (Section 7 accelerator) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_tile, ref

jax.config.update("jax_enable_x64", True)


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


class TestMatmulTile:
    def test_identity(self):
        x = jnp.eye(16, dtype=jnp.float32)
        out = matmul_tile.matmul(x, x, bm=8, bn=8, bk=8)
        np.testing.assert_allclose(np.asarray(out), np.eye(16), atol=1e-6)

    def test_single_block_equals_dot(self):
        x, y = _rand((8, 8), 0), _rand((8, 8), 1)
        out = matmul_tile.matmul(x, y, bm=8, bn=8, bk=8)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.matmul(x, y)), rtol=1e-5)

    def test_paper_tile_128(self):
        """The exact HLS geometry: one 128x128x128 tile."""
        x, y = _rand((128, 128), 2), _rand((128, 128), 3)
        out = matmul_tile.matmul(x, y, bm=128, bn=128, bk=128)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.matmul(x, y)), rtol=2e-4, atol=1e-3)

    def test_tiled_256_with_paper_tile(self):
        """2x2x2 grid of 128-tiles — the §7 composed accelerator."""
        x, y = _rand((256, 256), 4), _rand((256, 256), 5)
        out = matmul_tile.matmul(x, y)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.matmul(x, y)), rtol=2e-4, atol=1e-3)

    def test_rectangular(self):
        x, y = _rand((16, 32), 6), _rand((32, 8), 7)
        out = matmul_tile.matmul(x, y, bm=8, bn=8, bk=8)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.matmul(x, y)), rtol=1e-4, atol=1e-4)

    def test_rejects_non_multiple_shapes(self):
        x = jnp.zeros((10, 8), jnp.float32)
        y = jnp.zeros((8, 8), jnp.float32)
        with pytest.raises(AssertionError):
            matmul_tile.matmul(x, y, bm=8, bn=8, bk=8)

    def test_rejects_mismatched_inner(self):
        x = jnp.zeros((8, 16), jnp.float32)
        y = jnp.zeros((8, 8), jnp.float32)
        with pytest.raises(AssertionError):
            matmul_tile.matmul(x, y, bm=8, bn=8, bk=8)

    def test_vmem_footprint_paper_tile(self):
        # 3 x 128x128 f32 blocks = 192 KiB — must fit VMEM (16 MiB)
        assert matmul_tile.vmem_bytes() == 192 * 1024
        assert matmul_tile.vmem_bytes() < 16 * 1024 * 1024

    @settings(max_examples=10, deadline=None)
    @given(
        mi=st.integers(1, 3), ni=st.integers(1, 3), ki=st.integers(1, 3),
        bm=st.sampled_from([4, 8]), bn=st.sampled_from([4, 8]),
        bk=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1),
    )
    def test_property_matches_oracle(self, mi, ni, ki, bm, bn, bk, seed):
        m, n, k = mi * bm, ni * bn, ki * bk
        x, y = _rand((m, k), seed), _rand((k, n), seed + 1)
        out = matmul_tile.matmul(x, y, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.matmul(x, y)), rtol=1e-4,
            atol=1e-4)
