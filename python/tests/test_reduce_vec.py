"""Pallas allreduce ALU (Section 4.7 accelerator) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import reduce_vec, ref

jax.config.update("jax_enable_x64", True)


def _mk(dtype_name, n, seed):
    rng = np.random.default_rng(seed)
    dt = reduce_vec.DTYPES[dtype_name]
    if dtype_name == "i32":
        return jnp.asarray(rng.integers(-1000, 1000, n), dtype=dt)
    return jnp.asarray(rng.standard_normal(n), dtype=dt)


class TestReduceVec:
    @pytest.mark.parametrize("op", reduce_vec.OPS)
    @pytest.mark.parametrize("dtype", list(reduce_vec.DTYPES))
    def test_one_block_all_ops_dtypes(self, op, dtype):
        n = reduce_vec.BLOCK_BYTES // reduce_vec.DTYPES[dtype](0).itemsize
        a, b = _mk(dtype, n, 1), _mk(dtype, n, 2)
        out = reduce_vec.combine(a, b, op=op)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref.combine(a, b, op)))

    def test_multi_block(self):
        # 4 KB vector = 16 hardware blocks of 256 B
        a, b = _mk("f32", 1024, 3), _mk("f32", 1024, 4)
        out = reduce_vec.combine(a, b, op="sum")
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(a) + np.asarray(b), rtol=1e-6)

    def test_rejects_partial_block(self):
        a = jnp.zeros((63,), jnp.float32)
        with pytest.raises(AssertionError):
            reduce_vec.combine(a, a, op="sum")

    def test_rejects_unknown_op(self):
        a = jnp.zeros((64,), jnp.float32)
        with pytest.raises(AssertionError):
            reduce_vec.combine(a, a, op="prod")

    def test_sum_is_commutative_and_associative_enough(self):
        a, b, c = (_mk("i32", 64, s) for s in (5, 6, 7))
        ab_c = reduce_vec.combine(reduce_vec.combine(a, b), c)
        a_bc = reduce_vec.combine(a, reduce_vec.combine(b, c))
        np.testing.assert_array_equal(np.asarray(ab_c), np.asarray(a_bc))

    @settings(max_examples=20, deadline=None)
    @given(
        op=st.sampled_from(reduce_vec.OPS),
        dtype=st.sampled_from(sorted(reduce_vec.DTYPES)),
        blocks=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_matches_oracle(self, op, dtype, blocks, seed):
        n = blocks * (reduce_vec.BLOCK_BYTES
                      // reduce_vec.DTYPES[dtype](0).itemsize)
        a, b = _mk(dtype, n, seed), _mk(dtype, n, seed + 1)
        out = reduce_vec.combine(a, b, op=op)
        expect = ref.combine(a, b, op)
        if dtype == "i32":
            np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
        else:
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(expect), rtol=1e-6)
