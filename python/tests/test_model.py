"""Layer-2 model: CG composition converges and matches a numpy CG."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref, stencil27

jax.config.update("jax_enable_x64", True)


def _numpy_cg(b, iters):
    """Plain numpy CG against the roll-oracle operator."""
    def amul(v):
        return np.asarray(ref.spmv(stencil27.pad_halo(jnp.asarray(v))))

    x = np.zeros_like(b)
    r = b.copy()
    p = r.copy()
    rr = float((r * r).sum())
    hist = [np.sqrt(rr)]
    for _ in range(iters):
        ap = amul(p)
        alpha = rr / float((p * ap).sum())
        x += alpha * p
        r -= alpha * ap
        rr_new = float((r * r).sum())
        p = r + (rr_new / rr) * p
        rr = rr_new
        hist.append(np.sqrt(rr))
    return x, np.asarray(hist)


class TestCgModel:
    def test_cg_pre_post_roundtrip(self):
        rng = np.random.default_rng(0)
        n = 6
        p = jnp.asarray(rng.standard_normal((n, n, n)), jnp.float32)
        ap, pap = model.cg_pre(stencil27.pad_halo(p))
        np.testing.assert_allclose(
            np.asarray(pap)[0],
            float((np.asarray(p) * np.asarray(ap)).sum()), rtol=1e-4)

    def test_cg_converges(self):
        rng = np.random.default_rng(1)
        n = 8
        b = jnp.asarray(rng.standard_normal((n, n, n)), jnp.float32)
        x, hist = model.cg_solve_single(b, iters=25)
        hist = np.asarray(hist)
        assert hist[-1] < 1e-3 * hist[0], f"no convergence: {hist}"
        # and the solution actually solves the system
        ax = np.asarray(ref.spmv(stencil27.pad_halo(x)))
        np.testing.assert_allclose(ax, np.asarray(b), rtol=0, atol=2e-3)

    def test_cg_matches_numpy_cg(self):
        rng = np.random.default_rng(2)
        n = 6
        b = rng.standard_normal((n, n, n)).astype(np.float32)
        x_np, hist_np = _numpy_cg(b.copy(), 10)
        x_jx, hist_jx = model.cg_solve_single(jnp.asarray(b), 10)
        np.testing.assert_allclose(np.asarray(hist_jx), hist_np,
                                   rtol=5e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(x_jx), x_np, rtol=0, atol=5e-3)

    def test_residual_strictly_decreasing_early(self):
        rng = np.random.default_rng(3)
        b = jnp.asarray(rng.standard_normal((8, 8, 8)), jnp.float32)
        _, hist = model.cg_solve_single(b, iters=8)
        h = np.asarray(hist)
        assert (h[1:6] < h[:5]).all(), f"residuals not decreasing: {h}"


class TestAotRegistry:
    def test_registry_entries_lower(self):
        """Every registry entry must trace + lower without error (the
        manifest signature path) — catches shape/registry drift early."""
        from compile import aot
        for name, fn, args in aot.registry():
            lowered = jax.jit(fn).lower(*args)
            flat, _ = jax.tree.flatten(lowered.out_info)
            assert len(flat) >= 1, name

    def test_manifest_matches_artifacts(self):
        import os
        art = os.path.join(os.path.dirname(__file__), "../../artifacts")
        if not os.path.exists(os.path.join(art, "manifest.txt")):
            import pytest
            pytest.skip("artifacts not built")
        from compile import aot
        names = {e[0] for e in aot.registry()}
        with open(os.path.join(art, "manifest.txt")) as f:
            lines = [l.split()[0] for l in f if l.strip()]
        assert set(lines) == names
        for n in lines:
            assert os.path.exists(os.path.join(art, f"{n}.hlo.txt")), n

    def test_hlo_text_is_parseable_entry(self):
        import os
        art = os.path.join(os.path.dirname(__file__), "../../artifacts")
        path = os.path.join(art, "matmul_tile128.hlo.txt")
        if not os.path.exists(path):
            import pytest
            pytest.skip("artifacts not built")
        text = open(path).read()
        assert "ENTRY" in text and "f32[128,128]" in text
