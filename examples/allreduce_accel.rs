//! The Allreduce accelerator (paper §4.7 / Fig 19) end to end:
//! the reduction-tree arithmetic runs through the AOT Pallas `reduce_vec`
//! ALU via PJRT, the latency comes from the simulated NI accelerator
//! model, and the software baseline is the recursive-doubling ExaNet-MPI
//! collective.
//!
//!     make artifacts && cargo run --release --example allreduce_accel

use exanest::accel::{AccelAllreduce, AccelOp};
use exanest::apps::osu_allreduce;
use exanest::mpi::{Placement, World};
use exanest::runtime::Executor;
use exanest::sim::Rng;
use exanest::topology::SystemConfig;

fn main() -> exanest::errors::Result<()> {
    let cfg = SystemConfig::prototype();
    let mut exec = Executor::open_default()?;
    let mut rng = Rng::new(7);

    // 16 ranks (one per MPSoC, whole QFDBs), 256-byte vectors = 64 f32.
    let nranks = 16;
    let contributions: Vec<Vec<f32>> = (0..nranks).map(|_| rng.f32_vec(64)).collect();

    for op in [AccelOp::Sum, AccelOp::Min, AccelOp::Max] {
        let mut world = World::new(cfg.clone(), nranks, Placement::PerMpsoc);
        let (lat, out) =
            AccelAllreduce::allreduce_f32(&mut world, &mut exec, op, &contributions)?;
        let native = AccelAllreduce::allreduce_f32_native(op, &contributions);
        let max_err = out
            .iter()
            .zip(&native)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "{op:?}: accelerated latency {:.2} us, PJRT-vs-native max err {max_err:.2e}",
            lat.us()
        );
        assert!(max_err < 1e-4, "accelerator ALU numerics diverged");
    }

    // Fig 19 excerpt: HW vs SW latency across rank counts at 256 B.
    println!("\nFig 19 @256 B:");
    for nranks in [16usize, 32, 64, 128] {
        let sw = osu_allreduce(&cfg, nranks, 256, 5, Placement::PerMpsoc);
        let mut world = World::new(cfg.clone(), nranks, Placement::PerMpsoc);
        let hw = AccelAllreduce::latency(&mut world, 256);
        println!(
            "  {nranks:>4} ranks: software {:>7.2} us, accelerator {:>6.2} us ({:.1}% faster)",
            sw.us(),
            hw.us(),
            100.0 * (1.0 - hw.ns() / sw.ns())
        );
    }
    println!("paper: accelerator wins by up to 83-88%; 16r/256B = 6.79 us");
    Ok(())
}
