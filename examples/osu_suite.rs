//! The OSU microbenchmark sweep (Table 2, Figs 14-17 in miniature):
//! latency per path class, bandwidth, and the collectives.
//!
//!     cargo run --release --example osu_suite

use exanest::apps::osu::{self, OsuPath};
use exanest::mpi::Placement;
use exanest::topology::SystemConfig;

fn main() {
    let cfg = SystemConfig::prototype();

    println!("osu_latency (0 B) per path class [Table 2]:");
    for p in OsuPath::ALL {
        let lat = osu::osu_latency(&cfg, p, 0, 100);
        println!("  {:<18} {:>7.3} us", p.label(), lat.us());
    }

    println!("\nosu_bw 4 MB [Fig 15]:");
    for p in [OsuPath::IntraQfdbSh, OsuPath::IntraMezzSh, OsuPath::InterMezz312] {
        let bw = osu::osu_bw(&cfg, p, 4 << 20, 64);
        let bi = osu::osu_bibw(&cfg, p, 4 << 20, 64);
        println!("  {:<18} uni {:>6.2} Gb/s   bi {:>6.2} Gb/s", p.label(), bw, bi);
    }

    println!("\nosu_bcast 1 B [Fig 16]:");
    for n in [4usize, 16, 64, 256, 512] {
        let lat = osu::osu_bcast(&cfg, n, 1, 10, 42);
        println!("  {n:>4} ranks: {:>7.3} us", lat.us());
    }

    println!("\nosu_allreduce 4 B [Fig 17]:");
    for n in [4usize, 16, 64, 256, 512] {
        let lat = osu::osu_allreduce(&cfg, n, 4, 10, Placement::PerCore);
        println!("  {n:>4} ranks: {:>7.3} us", lat.us());
    }
}
