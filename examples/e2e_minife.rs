//! End-to-end driver: a distributed miniFE/HPCG-style conjugate-gradient
//! solve where the *numerics* run through the AOT-compiled Pallas kernels
//! (PJRT, no python at runtime) and every halo exchange and dot-product
//! allreduce is timed by the simulated ExaNet fabric + ExaNet-MPI runtime.
//!
//! Problem: A x = b with the HPCG 27-point operator on a 48^3 grid,
//! partitioned 2x2x2 over 8 simulated ranks (local blocks 24^3).
//! Validation: the residual curve must match a single-rank 48^3 solve of
//! the same system (same artifacts), and converge.
//!
//!     make artifacts && cargo run --release --example e2e_minife

use exanest::mpi::{collectives, pt2pt, Placement, World};
use exanest::runtime::Executor;
use exanest::sim::{Rng, SimDuration};
use exanest::topology::SystemConfig;

const N: usize = 48; // global grid edge
const P: usize = 2; // ranks per dimension
const NL: usize = N / P; // local block edge (24)
const ITERS: usize = 30;

/// Gather the halo-padded local block of rank (cx,cy,cz) from the
/// distributed field (numerics of the halo exchange; timing is charged
/// separately through the simulated fabric).
fn gather_padded(field: &[Vec<f32>], c: (usize, usize, usize)) -> Vec<f32> {
    let np = NL + 2;
    let mut out = vec![0.0f32; np * np * np];
    let (ox, oy, oz) = (c.0 * NL, c.1 * NL, c.2 * NL);
    for z in 0..np {
        for y in 0..np {
            for x in 0..np {
                let (gz, gy, gx) = (
                    oz as isize + z as isize - 1,
                    oy as isize + y as isize - 1,
                    ox as isize + x as isize - 1,
                );
                if gz < 0 || gy < 0 || gx < 0
                    || gz >= N as isize || gy >= N as isize || gx >= N as isize
                {
                    continue; // zero Dirichlet boundary
                }
                let (gz, gy, gx) = (gz as usize, gy as usize, gx as usize);
                let rank = (gx / NL) + (gy / NL) * P + (gz / NL) * P * P;
                let (lz, ly, lx) = (gz % NL, gy % NL, gx % NL);
                out[(z * np + y) * np + x] =
                    field[rank][(lz * NL + ly) * NL + lx];
            }
        }
    }
    out
}

fn rank_coord(r: usize) -> (usize, usize, usize) {
    (r % P, (r / P) % P, r / (P * P))
}

/// Charge the simulated cost of one halo exchange + compute phase.
fn charge_iteration(world: &mut World, compute: SimDuration) {
    for c in world.clocks.iter_mut() {
        *c += compute;
    }
    let face = NL * NL * 4;
    for dim in 0..3 {
        for r in 0..world.nranks() {
            let c = rank_coord(r);
            let mut nc = c;
            match dim {
                0 => nc.0 = (c.0 + 1) % P,
                1 => nc.1 = (c.1 + 1) % P,
                _ => nc.2 = (c.2 + 1) % P,
            }
            let n = rank_coord_inv(nc);
            if r < n {
                pt2pt::sendrecv_exchange(world, r, n, face);
            }
        }
    }
}

fn rank_coord_inv(c: (usize, usize, usize)) -> usize {
    c.0 + c.1 * P + c.2 * P * P
}

fn main() -> exanest::errors::Result<()> {
    let mut exec = Executor::open_default()?;
    let nranks = P * P * P;
    let mut world = World::new(SystemConfig::prototype(), nranks, Placement::PerCore);
    let mut rng = Rng::new(2023);

    // Right-hand side, distributed.
    let global_b: Vec<f32> = rng.f32_vec(N * N * N);
    let mut b_local: Vec<Vec<f32>> = vec![vec![0.0; NL * NL * NL]; nranks];
    for gz in 0..N {
        for gy in 0..N {
            for gx in 0..N {
                let rank = (gx / NL) + (gy / NL) * P + (gz / NL) * P * P;
                b_local[rank][((gz % NL) * NL + gy % NL) * NL + gx % NL] =
                    global_b[(gz * N + gy) * N + gx];
            }
        }
    }

    // ---- distributed CG over the simulated machine --------------------
    let mut x: Vec<Vec<f32>> = vec![vec![0.0; NL * NL * NL]; nranks];
    let mut r = b_local.clone();
    let mut p = r.clone();
    let mut rr: f64 = r
        .iter()
        .flat_map(|v| v.iter())
        .map(|&v| (v as f64) * (v as f64))
        .sum();
    let mut hist = vec![rr.sqrt()];
    // per-iteration local compute, minife-calibrated
    let compute = SimDuration::from_secs((NL * NL * NL) as f64 * 7.0e-8);
    let t_start = world.max_clock();

    for _ in 0..ITERS {
        charge_iteration(&mut world, compute);
        // Ap = A p; local pAp — Pallas cg_pre through PJRT, per rank
        let mut ap = Vec::with_capacity(nranks);
        let mut pap = 0.0f64;
        for rank in 0..nranks {
            let padded = gather_padded(&p, rank_coord(rank));
            let out = exec.run_f32("cg_pre_24", &[&padded])?;
            pap += out[1][0] as f64;
            ap.push(out[0].clone());
        }
        collectives::allreduce(&mut world, 8);
        let alpha = (rr / pap) as f32;
        // x += alpha p; r -= alpha Ap; local rr
        let mut rr_new = 0.0f64;
        for rank in 0..nranks {
            let out = exec.run_f32(
                "cg_post_24",
                &[&x[rank], &r[rank], &p[rank], &ap[rank], &[alpha]],
            )?;
            x[rank] = out[0].clone();
            r[rank] = out[1].clone();
            rr_new += out[2][0] as f64;
        }
        collectives::allreduce(&mut world, 8);
        let beta = (rr_new / rr) as f32;
        for rank in 0..nranks {
            let out = exec.run_f32("cg_update_p", &[&r[rank], &p[rank], &[beta]])
                .or_else(|_| exec.run_f32("cg_update_p_24", &[&r[rank], &p[rank], &[beta]]))?;
            p[rank] = out[0].clone();
        }
        rr = rr_new;
        hist.push(rr.sqrt());
    }
    let sim_time_8 = (world.max_clock() - t_start).secs();

    // ---- single-rank reference on the same system ---------------------
    let mut x1 = vec![0.0f32; N * N * N];
    let mut r1 = global_b.clone();
    let mut p1 = r1.clone();
    let mut rr1: f64 = r1.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let mut hist1 = vec![rr1.sqrt()];
    for _ in 0..ITERS {
        let mut padded = vec![0.0f32; (N + 2) * (N + 2) * (N + 2)];
        for z in 0..N {
            for y in 0..N {
                for xx in 0..N {
                    padded[((z + 1) * (N + 2) + y + 1) * (N + 2) + xx + 1] =
                        p1[(z * N + y) * N + xx];
                }
            }
        }
        let pre = exec.run_f32("cg_pre_48", &[&padded])?;
        let alpha = (rr1 / pre[1][0] as f64) as f32;
        let post = exec.run_f32("cg_post_48", &[&x1, &r1, &p1, &pre[0], &[alpha]])?;
        x1 = post[0].clone();
        r1 = post[1].clone();
        let rr_new = post[2][0] as f64;
        let beta = (rr_new / rr1) as f32;
        let upd = exec.run_f32("cg_update_p_48", &[&r1, &p1, &[beta]])?;
        p1 = upd[0].clone();
        rr1 = rr_new;
        hist1.push(rr1.sqrt());
    }

    // ---- report + validation ------------------------------------------
    println!("e2e miniFE-style CG, 48^3 grid, 8 simulated ranks, {ITERS} iters");
    println!("residual curve (distributed): ");
    for (i, h) in hist.iter().enumerate().step_by(5) {
        println!("  iter {i:>3}: {h:.6e}");
    }
    let reduction = hist[0] / hist[hist.len() - 1];
    println!("residual reduction: {reduction:.1}x");
    assert!(reduction > 20.0, "CG failed to converge");

    // distributed must track the single-rank reference
    let mut max_rel = 0.0f64;
    for (a, b) in hist.iter().zip(&hist1) {
        max_rel = max_rel.max(((a - b) / b).abs());
    }
    println!("max relative residual deviation vs single-rank: {max_rel:.3e}");
    assert!(max_rel < 1e-3, "distributed CG diverged from reference");

    println!("simulated time (8 ranks):   {:.3} ms", sim_time_8 * 1e3);
    let t1 = ITERS as f64 * (N * N * N) as f64 * 7.0e-8;
    println!("modelled single-rank time:  {:.3} ms", t1 * 1e3);
    println!("parallel efficiency:        {:.1}%", 100.0 * t1 / (8.0 * sim_time_8));
    println!("PJRT kernel executions:     {}", exec.executions);
    println!("e2e OK");
    Ok(())
}
