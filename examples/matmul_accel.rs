//! The Section-7 matrix-multiplication accelerator: real numerics through
//! the AOT Pallas tile (PJRT), performance through the cycle model.
//!
//!     make artifacts && cargo run --release --example matmul_accel

use exanest::accel::MatmulAccel;
use exanest::runtime::Executor;
use exanest::sim::Rng;

fn naive_matmul(n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                c[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    c
}

fn main() -> exanest::errors::Result<()> {
    let mut exec = Executor::open_default()?;
    let accel = MatmulAccel::default();
    let mut rng = Rng::new(11);

    // Numerics: the 256x256 multiply through the tiled Pallas kernel
    // (2x2x2 grid of the paper's 128^3 tile) vs a naive rust reference.
    let n = 256;
    let a = rng.f32_vec(n * n);
    let b = rng.f32_vec(n * n);
    let got = accel.multiply_f32(&mut exec, n, &a, &b)?;
    let want = naive_matmul(n, &a, &b);
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("matmul_256 via PJRT: max |err| vs naive rust = {max_err:.3e}");
    assert!(max_err < 1e-2, "tile numerics diverged");

    // Performance: the paper's cycle model.
    println!("\ncycle model (one ZU9EG MPSoC):");
    for size in [512usize, 1024, 2048] {
        println!(
            "  n={size:>5}: {:>8.3} ms, {:>6.1} GFLOPS, {:>4.1} GFLOPS/W",
            accel.time_seconds(size) * 1e3,
            accel.gflops(size),
            accel.gflops_per_watt(size)
        );
    }
    println!(
        "QFDB (4 MPSoCs) sustained: {:.2} TFLOP/s (paper: >1 TFLOP/s)",
        accel.qfdb_tflops(1024)
    );
    let (l, f, d, br) = accel.utilisation();
    println!("tile utilisation: {l:.0}% LUT {f:.0}% FF {d:.0}% DSP {br:.0}% BRAM (paper 56/55/82/46)");
    Ok(())
}
