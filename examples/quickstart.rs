//! Quickstart: build the simulated rack, classify a few paths (Table 1),
//! send messages through ExaNet-MPI, and run a kernel through PJRT.
//!
//!     make artifacts && cargo run --release --example quickstart

use exanest::mpi::{pt2pt, Placement, World};
use exanest::runtime::Executor;
use exanest::topology::SystemConfig;

fn main() -> anyhow::Result<()> {
    // 1. The full-scale prototype: 8 blades, 32 QFDBs, 128 MPSoCs, 512 cores.
    let cfg = SystemConfig::prototype();
    println!(
        "prototype: {} QFDBs / {} MPSoCs / {} A53 cores, torus {:?}",
        cfg.num_qfdbs(),
        cfg.num_mpsocs(),
        cfg.num_cores(),
        cfg.torus_dims()
    );

    // 2. Route + classify a path (paper Table 1).
    let mut world = World::new(cfg.clone(), 512, Placement::PerCore);
    let a = world.fabric.topo.mpsoc(0, 0, 1);
    let b = world.fabric.topo.mpsoc(6, 1, 2);
    let path = world.fabric.route(a, b);
    println!(
        "path {:?} -> {:?}: class {}, {} hops, {} routers",
        a,
        b,
        path.class(),
        path.hops().len(),
        path.routers
    );

    // 3. An MPI message between two far ranks: eager vs rendez-vous.
    let r = pt2pt::send_recv(&mut world, 0, 511, 8);
    println!("eager 8 B rank0 -> rank511: {:.3} us", r.recv_done.us());
    world.reset();
    let r = pt2pt::send_recv(&mut world, 0, 511, 1 << 20);
    println!("rendez-vous 1 MB rank0 -> rank511: {:.3} us", r.recv_done.us());

    // 4. Execute an AOT Pallas kernel (the Section-7 accelerator tile)
    //    through PJRT — python is not involved at runtime.
    let mut exec = Executor::open_default()?;
    let n = 128;
    let a_mat = vec![1.0f32; n * n];
    let b_mat: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32).collect();
    let out = exec.run_f32("matmul_tile128", &[&a_mat, &b_mat])?;
    println!(
        "matmul_tile128 via PJRT: out[0] = {} (executions: {})",
        out[0][0], exec.executions
    );
    Ok(())
}
