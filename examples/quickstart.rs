//! Quickstart: build the simulated rack, classify a few paths (Table 1),
//! send messages through ExaNet-MPI — blocking and nonblocking — and run
//! a kernel through PJRT when the artifacts are available.
//!
//!     cargo run --release --example quickstart
//!     # with real numerics: make artifacts && cargo run --release --example quickstart

use exanest::mpi::{progress, pt2pt, Placement, World};
use exanest::runtime::Executor;
use exanest::sim::SimDuration;
use exanest::topology::SystemConfig;

fn main() -> exanest::errors::Result<()> {
    // 1. The full-scale prototype: 8 blades, 32 QFDBs, 128 MPSoCs, 512 cores.
    let cfg = SystemConfig::prototype();
    println!(
        "prototype: {} QFDBs / {} MPSoCs / {} A53 cores, torus {:?}",
        cfg.num_qfdbs(),
        cfg.num_mpsocs(),
        cfg.num_cores(),
        cfg.torus_dims()
    );

    // 2. Route + classify a path (paper Table 1).
    let mut world = World::new(cfg.clone(), 512, Placement::PerCore);
    let a = world.fabric.topo.mpsoc(0, 0, 1);
    let b = world.fabric.topo.mpsoc(6, 1, 2);
    let path = world.fabric.route(a, b);
    println!(
        "path {:?} -> {:?}: class {}, {} hops, {} routers",
        a,
        b,
        path.class(),
        path.hops().len(),
        path.routers
    );

    // 3. Blocking MPI between two far ranks: eager vs rendez-vous.
    let r = pt2pt::send_recv(&mut world, 0, 511, 8);
    println!("eager 8 B rank0 -> rank511: {:.3} us", r.recv_done.us());
    world.reset();
    let r = pt2pt::send_recv(&mut world, 0, 511, 1 << 20);
    println!("rendez-vous 1 MB rank0 -> rank511: {:.3} us", r.recv_done.us());

    // 4. The same transfer nonblocking: isend, overlap 500 us of local
    //    compute while the RDMA engine streams, then wait.  The sender's
    //    timeline ends at max(compute, transfer) instead of their sum.
    world.reset();
    let s = progress::isend(&mut world, 0, 511, 1 << 20);
    let rv = progress::irecv(&mut world, 511, 0, 1 << 20);
    world.clocks[0] += SimDuration::from_us(500.0); // overlapped compute
    progress::wait(&mut world, s);
    println!(
        "nonblocking 1 MB + 500 us compute: sender done at {:.3} us",
        world.clocks[0].us()
    );
    progress::wait(&mut world, rv);

    // 5. Execute an AOT Pallas kernel (the Section-7 accelerator tile)
    //    through PJRT — python is not involved at runtime.  Skipped
    //    gracefully when the artifacts / PJRT runtime are absent.
    match Executor::open_default() {
        Ok(mut exec) => {
            let n = 128;
            let a_mat = vec![1.0f32; n * n];
            let b_mat: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32).collect();
            let out = exec.run_f32("matmul_tile128", &[&a_mat, &b_mat])?;
            println!(
                "matmul_tile128 via PJRT: out[0] = {} (executions: {})",
                out[0][0], exec.executions
            );
        }
        Err(e) => println!("skipping the PJRT demo: {e}"),
    }
    Ok(())
}
