#!/usr/bin/env python3
"""Diff two directories of BENCH_*.json suites and gate on regressions.

The bench regression gate of DESIGN.md §16: CI runs the candidate
commit's suites into one directory, fetches the baseline's into another,
and this script compares them metric-by-metric with per-metric
tolerances.  Simulated quantities (latencies in simulated time,
efficiencies, blame shares) are deterministic, so they get tight gates;
wall-clock quantities (wall_s, events_per_sec, ns/iter benchmark
medians) are runner-noisy, so they get loose ones.  Anything without a
matching rule is reported as informational drift but never fails the
gate.

Output is a markdown table (stdout, plus $GITHUB_STEP_SUMMARY when set,
plus --markdown FILE), one row per compared value.  Exit codes: 0 ok,
1 regression, 2 usage/IO error.

Bootstrap mode: if the baseline directory is missing or holds no
BENCH_*.json, the gate prints a notice and exits 0 so the first run of a
new pipeline (or a new suite) can seed the baseline instead of failing.
Suites or metrics present on only one side are reported but do not fail
the gate either — adding a bench must not require a two-step dance.

Stdlib only — no pip installs.

Usage: bench_diff.py <baseline_dir> <candidate_dir> [--markdown FILE]
"""

import fnmatch
import json
import os
import sys

# (metric-name pattern, direction, relative tolerance).  First match
# wins.  direction "lower" = smaller is better, "higher" = bigger is
# better, "exact" = any change beyond the tolerance regresses in either
# direction (deterministic simulated quantities that simply must not
# drift).  Patterns are fnmatch globs against "suite/metric".
RULES = [
    # Wall-clock: runner-dependent, loose gates.
    ("*/wall_s", "lower", 0.50),
    ("*/events_per_sec", "higher", 0.40),
    ("*/eps_*", "higher", 0.40),
    # Simulated time and derived quality metrics: deterministic given
    # one config fingerprint, so a small tolerance only absorbs honest
    # recalibration, not noise.
    ("*/latency_us", "lower", 0.02),
    ("*/critical_path_us", "lower", 0.02),
    ("*/lib_ni_us", "exact", 0.02),
    ("*/efficiency*", "higher", 0.02),
    ("*/jain*", "higher", 0.02),
    ("*/blame/*_share", "exact", 0.05),
    ("*/scenario/*", "exact", 0.02),
    # ns/iter timing benchmarks (median): wall-clock again.
    ("bench:*", "lower", 0.50),
]

INFO = ("info", 0.0)  # no matching rule: report, never gate


def fail(msg):
    print(f"bench_diff: {msg}", file=sys.stderr)
    sys.exit(2)


def load_suites(d):
    """{suite name: parsed json} for every BENCH_*.json in d."""
    suites = {}
    if not os.path.isdir(d):
        return suites
    for name in sorted(os.listdir(d)):
        if not (name.startswith("BENCH_") and name.endswith(".json")):
            continue
        path = os.path.join(d, name)
        try:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            fail(f"cannot parse {path}: {e}")
        suites[doc.get("suite", name[len("BENCH_"):-len(".json")])] = doc
    return suites


def flatten(doc):
    """{comparison key: value} for one suite document.

    Scalar metrics become "suite/name"; timing benchmarks become
    "bench:suite/name" keyed on their median so the rule table can gate
    wall-clock entries separately from simulated ones.
    """
    suite = doc.get("suite", "?")
    out = {}
    for m in doc.get("metrics", []):
        if isinstance(m.get("value"), (int, float)):
            out[f"{suite}/{m['name']}"] = float(m["value"])
    for b in doc.get("benchmarks", []):
        if isinstance(b.get("median_ns"), (int, float)):
            out[f"bench:{suite}/{b['name']}"] = float(b["median_ns"])
    return out


def rule_for(key):
    for pat, direction, tol in RULES:
        if fnmatch.fnmatch(key, pat):
            return direction, tol
    return INFO


def verdict(key, base, cand):
    """(status, delta) where status is ok/regressed/improved/info."""
    direction, tol = rule_for(key)
    if base == 0.0:
        delta = 0.0 if cand == 0.0 else float("inf")
    else:
        delta = (cand - base) / abs(base)
    if direction == "info":
        return "info", delta
    worse = (
        delta > tol
        if direction == "lower"
        else -delta > tol
        if direction == "higher"
        else abs(delta) > tol
    )
    if worse:
        return "regressed", delta
    better = (
        delta < -tol
        if direction == "lower"
        else delta > tol
        if direction == "higher"
        else False
    )
    return ("improved" if better else "ok"), delta


def fmt(v):
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


def main(argv):
    md_file = None
    args = []
    it = iter(argv)
    for a in it:
        if a == "--markdown":
            md_file = next(it, None)
            if md_file is None:
                fail("--markdown needs a file argument")
        else:
            args.append(a)
    if len(args) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2

    base_dir, cand_dir = args
    base = load_suites(base_dir)
    cand = load_suites(cand_dir)
    if not cand:
        fail(f"candidate directory {cand_dir} holds no BENCH_*.json")
    if not base:
        print(
            f"bench_diff: BOOTSTRAP: no baseline in {base_dir}; "
            f"candidate ({len(cand)} suites) becomes the new baseline"
        )
        return 0

    lines = ["| metric | baseline | candidate | Δ | verdict |",
             "|---|---:|---:|---:|---|"]
    regressions = []
    compared = 0
    for suite in sorted(set(base) | set(cand)):
        if suite not in base or suite not in cand:
            side = "baseline" if suite in base else "candidate"
            lines.append(f"| {suite} (suite only in {side}) | | | | skipped |")
            continue
        b_doc, c_doc = base[suite], cand[suite]
        if b_doc.get("config_hash") not in (None, "unstamped") and b_doc.get(
            "config_hash"
        ) != c_doc.get("config_hash"):
            lines.append(
                f"| {suite} (config_hash "
                f"{b_doc['config_hash']} → {c_doc.get('config_hash')}) "
                f"| | | | skipped: different machine model |"
            )
            continue
        b_vals, c_vals = flatten(b_doc), flatten(c_doc)
        for key in sorted(set(b_vals) | set(c_vals)):
            if key not in b_vals or key not in c_vals:
                side = "baseline" if key in b_vals else "candidate"
                lines.append(f"| {key} | | | | only in {side} |")
                continue
            bv, cv = b_vals[key], c_vals[key]
            status, delta = verdict(key, bv, cv)
            compared += 1
            if status == "regressed":
                regressions.append((key, bv, cv, delta))
            mark = {
                "ok": "ok",
                "info": "drift (not gated)" if bv != cv else "ok (not gated)",
                "improved": "**improved**",
                "regressed": "**REGRESSED**",
            }[status]
            pct = "n/a" if delta == float("inf") else f"{delta:+.1%}"
            lines.append(f"| {key} | {fmt(bv)} | {fmt(cv)} | {pct} | {mark} |")

    header = (
        f"### bench_diff: {compared} values compared, "
        f"{len(regressions)} regression(s)\n"
    )
    table = header + "\n".join(lines) + "\n"
    print(table)
    for dest in filter(None, [md_file, os.environ.get("GITHUB_STEP_SUMMARY")]):
        try:
            with open(dest, "a", encoding="utf-8") as f:
                f.write(table)
        except OSError as e:
            fail(f"cannot write {dest}: {e}")

    if regressions:
        for key, bv, cv, delta in regressions:
            print(
                f"bench_diff: REGRESSED: {key}: {fmt(bv)} -> {fmt(cv)} "
                f"({delta:+.1%})",
                file=sys.stderr,
            )
        return 1
    print(f"bench_diff: OK: no regressions across {compared} compared values")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
