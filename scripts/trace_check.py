#!/usr/bin/env python3
"""Validate a `repro --trace` export against the Chrome trace-event schema.

The flight recorder (DESIGN.md §13) exports complete-span ("X") events
plus process_name ("M") metadata for the five fixed tracks (the fifth,
"critical-path", appears when a blame/critical-path analysis ran —
DESIGN.md §16).  This check is what CI runs on the perf-smoke trace
artifact before uploading it: it guarantees the file is
Perfetto-loadable and internally consistent without needing Perfetto
itself.  Stdlib only — no pip installs.

Beyond field shapes it enforces flow continuity: a span whose args carry
a "parent" flow id must either resolve to a retained span with that flow
or be explicitly flagged `"truncated": true` (ring eviction stranded its
history, and the exporter collapses it to a zero-duration instant).  A
dangling parent without the flag means the exporter broke its promise.

Usage: trace_check.py <trace.json>
"""

import json
import sys

# Track -> pid mapping fixed by telemetry::export (DESIGN.md §13, §16).
REQUIRED_PROCESSES = {
    1: "mpi-ranks",
    2: "router-lanes",
    3: "sched-jobs",
    4: "par-runtime",
    5: "critical-path",
}

SPAN_FIELDS = ("name", "cat", "ph", "ts", "dur", "pid", "tid")


def fail(msg):
    print(f"trace_check: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot parse {path}: {e}")

    if not isinstance(doc, dict):
        fail("top level is not a JSON object")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents missing, not a list, or empty")

    other = doc.get("otherData")
    if not isinstance(other, dict):
        fail("otherData missing")
    for key in ("records", "dropped"):
        if not isinstance(other.get(key), int) or other[key] < 0:
            fail(f"otherData.{key} missing or not a non-negative integer")

    spans = [e for e in events if e.get("ph") == "X"]
    meta = [e for e in events if e.get("ph") == "M"]
    if len(spans) + len(meta) != len(events):
        phases = sorted({e.get("ph") for e in events} - {"X", "M"})
        fail(f"unexpected event phases {phases} (only X and M are emitted)")

    # Every declared track must carry process_name metadata so Perfetto
    # shows named lanes, and every span's pid must be one of them.
    named = {}
    for e in meta:
        if e.get("name") != "process_name":
            fail(f"unexpected metadata event {e.get('name')!r}")
        named[e.get("pid")] = e.get("args", {}).get("name")
    for pid, want in REQUIRED_PROCESSES.items():
        if named.get(pid) != want:
            fail(f"pid {pid} process_name is {named.get(pid)!r}, want {want!r}")

    if other["records"] != len(spans):
        fail(f"otherData.records = {other['records']} but {len(spans)} X events")

    flows = set()
    for e in spans:
        args = e.get("args")
        if isinstance(args, dict) and "flow" in args:
            flows.add(args["flow"])

    last_ts = {}
    crit_spans = 0
    parented = 0
    truncated = 0
    for i, e in enumerate(spans):
        for key in SPAN_FIELDS:
            if key not in e:
                fail(f"span {i} missing field {key!r}")
        if not isinstance(e["name"], str) or not e["name"]:
            fail(f"span {i} has an empty name")
        if not isinstance(e["ts"], (int, float)) or e["ts"] < 0:
            fail(f"span {i} has invalid ts {e['ts']!r}")
        if not isinstance(e["dur"], (int, float)) or e["dur"] < 0:
            fail(f"span {i} has negative dur {e['dur']!r}")
        if e["pid"] not in REQUIRED_PROCESSES:
            fail(f"span {i} pid {e['pid']!r} has no process_name metadata")
        if e["pid"] == 5:
            crit_spans += 1
        args = e.get("args")
        if not isinstance(args, dict) or "flow" not in args:
            fail(f"span {i} args missing the flow id")
        for key in args:
            if key not in ("flow", "aux", "parent", "truncated"):
                fail(f"span {i} has unexpected args key {key!r}")
        # Flow continuity (DESIGN.md §13): a causality link either
        # resolves or is flagged as truncated by ring eviction.
        if "parent" in args:
            parented += 1
            if not isinstance(args["parent"], int):
                fail(f"span {i} parent {args['parent']!r} is not an integer")
            if args["parent"] not in flows:
                if args.get("truncated") is not True:
                    fail(
                        f"span {i} parent flow {args['parent']} resolves to "
                        f"no retained span and is not flagged truncated"
                    )
                if e["dur"] != 0:
                    fail(f"span {i} is truncated but keeps dur {e['dur']!r}")
                truncated += 1
            elif args.get("truncated") is True:
                fail(f"span {i} flagged truncated but parent {args['parent']} resolves")
        elif args.get("truncated") is True:
            fail(f"span {i} flagged truncated without a parent link")
        # Export sorts records; Perfetto tolerates disorder but the
        # exporter promises per-file monotone start times.
        if e["ts"] < last_ts.get("all", 0):
            fail(f"span {i} ts {e['ts']} not monotone non-decreasing")
        last_ts["all"] = e["ts"]

    crit = f", {crit_spans} critical-path" if crit_spans else ""
    print(
        f"trace_check: OK: {len(spans)} spans on {len(named)} tracks{crit}, "
        f"{parented} linked ({truncated} truncated), "
        f"{other['dropped']} dropped ({path})"
    )


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        sys.exit(2)
    check(sys.argv[1])
